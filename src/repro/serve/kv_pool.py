"""Paged KV-cache block pool: fixed-size token blocks behind the serving engine.

DAnA's Striders replace dense hand-routed buffer access with an access engine
that walks page layouts directly (PAPER.md §Striders); the serving analogue is
vLLM-style paged attention. Instead of every decode slot owning a dense
``max_seq`` cache row — memory scaling with the *worst case* sequence — the
cache is a pool of fixed-size token blocks:

  * ``KVBlockPool`` — the allocator. A free list of physical block ids, a
    per-slot block table (logical block index -> physical block id),
    alloc-on-write (a block is mapped the first time a token position inside
    it is written), free-on-finish (a finished request returns its blocks),
    and reservation-based admission: a request is admitted only when the pool
    can cover its worst-case block demand, so a running request can never hit
    pool exhaustion mid-flight — OOM surfaces as *deferred admission*, never
    as a crash. Blocks are *refcounted*: several slots may map the same
    physical block read-only (``map_prefix``), ``release`` decrements and a
    block returns to the free list only when its count reaches zero, and a
    writer splits a shared block first (``cow`` — copy-on-write). Invariants
    (``free + in_use + quarantined == total`` over *distinct* blocks,
    refcount == number of table entries mapping a block, zero-refcount
    blocks live on exactly one free/quarantine list, table/length
    consistency) are pinned by ``tests/test_kv_pool.py``.
  * ``PrefixIndex`` — content hash of fully-written *feed* (prompt + carried
    output) blocks -> resident physical block id. A newly admitted request
    whose prompt starts with an indexed chain maps those blocks shared with
    a refcount bump and pays prefill only from its first divergent block.
    Keys are exact chained token tuples (no hash-collision exposure).
  * ``PagedKV`` — the serving-side composite: one pool for the full-width
    cache regions (GQA K/V, MLA latent) and, for models with sliding-window
    layers, a second pool whose logical rows are *ring* positions
    (``pos % ring_width``), so SWA ring semantics map onto blocks with the
    same validity story as the dense ring. With ``prefix_cache=True`` (full
    pool only — ring rows wrap, so a shared ring block would be missing the
    skipped positions' writes) it owns the prefix index and the shared
    admission / copy-on-write planning.

The device-side layout lives in ``models/attention.py``
(``gqa_decode_paged`` / ``mla_decode_paged``): cache leaves are block pools
``(num_blocks, block_size, ...)`` shared by every slot, and decode gathers a
slot's K/V through its block-table row. The pool here is pure host-side
bookkeeping (numpy) — the tables ship to the device as tiny int32 arrays.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


class PoolExhausted(RuntimeError):
    """A block was demanded that the free list cannot supply. Never raised
    when admission goes through ``can_admit``/``admit`` (reservations cover
    the worst case); reaching it means the admission protocol was bypassed."""


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to cover ``n_tokens`` token rows (ceil division)."""
    return -(-max(0, n_tokens) // block_size)


def prefix_keys(tokens, block_size: int) -> list[tuple]:
    """Chained content keys for every *full* block of ``tokens``.

    ``keys[j]`` identifies block ``j``'s contents *and* everything before it:
    ``keys[j] = (keys[j-1], tuple(tokens[j*bs:(j+1)*bs]))``. Chaining means a
    block id found under ``keys[j]`` is reusable only when the whole prefix
    matches — exactly the condition under which its KV rows are bit-identical
    to what the new request would write (KV at a position depends only on the
    token, the position and the params; see ``tests/test_serve_prefix.py``).
    Keys are exact nested tuples, not hashes, so collisions are impossible.
    """
    out: list[tuple] = []
    key: tuple = ()
    for j in range(len(tokens) // block_size):
        key = (key, tuple(int(t) for t in tokens[j * block_size:(j + 1) * block_size]))
        out.append(key)
    return out


class PrefixIndex:
    """Content key -> resident physical block id, maintained by the pool's
    refcount lifecycle: blocks register once fully written, evict the moment
    their refcount hits zero (the block id goes back to the free list and its
    contents will be overwritten by the next mapper). First writer wins —
    a duplicate key (another slot recomputing the same prefix privately) is
    ignored, as is a second key for an already-indexed block."""

    def __init__(self) -> None:
        self._by_key: dict[tuple, int] = {}
        self._by_block: dict[int, tuple] = {}

    def __len__(self) -> int:
        return len(self._by_key)

    def lookup(self, keys: list[tuple]) -> list[int]:
        """Longest chain of resident block ids matching ``keys`` head-first."""
        hits: list[int] = []
        for key in keys:
            bid = self._by_key.get(key)
            if bid is None:
                break
            hits.append(bid)
        return hits

    def register(self, key: tuple, bid: int) -> bool:
        if key in self._by_key or bid in self._by_block:
            return False
        self._by_key[key] = bid
        self._by_block[bid] = key
        return True

    def evict_block(self, bid: int) -> None:
        key = self._by_block.pop(bid, None)
        if key is not None:
            del self._by_key[key]

    def blocks(self) -> set[int]:
        return set(self._by_block)


class KVBlockPool:
    """Fixed-size token-block allocator with a free list, per-slot block
    tables, alloc-on-write and reservation-based admission.

    Logical rows (cache row indices: token positions for full regions, ring
    positions for SWA regions) map onto logical block indices ``row //
    block_size``; the table maps those to physical block ids. Unmapped table
    entries hold ``-1``.
    """

    def __init__(self, num_blocks: int, block_size: int, slots: int,
                 blocks_per_slot: int):
        if num_blocks < 0 or block_size < 1 or slots < 1 or blocks_per_slot < 1:
            raise ValueError(
                f"bad pool shape: num_blocks={num_blocks} "
                f"block_size={block_size} slots={slots} "
                f"blocks_per_slot={blocks_per_slot}"
            )
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.slots = int(slots)
        self.blocks_per_slot = int(blocks_per_slot)
        self.table = np.full((slots, blocks_per_slot), -1, np.int32)
        self.n_mapped = np.zeros(slots, np.int32)
        # LIFO free list: recycled blocks are re-mapped first, which is what
        # the parity tests lean on to prove stale contents are harmless
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._reserved = np.zeros(slots, np.int64)
        # fault-injection quarantine (serve/faults.py): blocks pulled out of
        # the free list by `shrink`, invisible to allocation until `grow`
        self._quarantined: list[int] = []
        # how many table entries map each physical block: 1 for a private
        # block, >1 when map_prefix shares it, 0 on the free/quarantine lists
        self.refcount = np.zeros(num_blocks, np.int32)
        # called with the block id whenever a refcount hits zero (PagedKV
        # wires this to PrefixIndex.evict_block: freed contents are dead)
        self.on_zero = None

    # -- accounting ----------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        """Blocks mapped into slot tables (quarantined blocks are withheld
        by a fault plan, not in use — they must not inflate the peak-usage
        metric or read as a leak after a drain)."""
        return self.num_blocks - len(self._free) - len(self._quarantined)

    @property
    def reserved_blocks(self) -> int:
        """Outstanding worst-case demand of admitted slots not yet mapped."""
        return int(self._reserved.sum())

    @property
    def quarantined_blocks(self) -> int:
        """Blocks a fault plan has shrunk out of the pool (0 normally)."""
        return len(self._quarantined)

    # -- fault injection -----------------------------------------------------
    def shrink(self, n: int) -> int:
        """Quarantine up to ``n`` free blocks (fault injection: capacity
        vanishes out from under outstanding reservations, so a later
        ``ensure`` may raise ``PoolExhausted`` mid-run — the *server's*
        preemption path, not this class, restores the admission invariant).
        Returns how many blocks were actually quarantined."""
        take = min(int(n), len(self._free))
        for _ in range(take):
            self._quarantined.append(self._free.pop())
        return take

    def grow(self, n: int | None = None) -> int:
        """Return up to ``n`` quarantined blocks (all when None) to the free
        list; returns how many came back."""
        back = len(self._quarantined) if n is None else min(int(n),
                                                            len(self._quarantined))
        for _ in range(back):
            self._free.append(self._quarantined.pop())
        return back

    # -- admission -----------------------------------------------------------
    @property
    def headroom(self) -> int:
        """Blocks admission may still promise: free minus outstanding
        reservations, floored at zero. A fault-plan ``shrink`` can pull
        ``free`` below ``reserved`` while admitted slots still hold their
        promises — that deficit must read as *no capacity* (admission stays
        closed until the server preempts or the plan heals), never as a
        negative number fed into a comparison."""
        return max(0, self.free_blocks - self.reserved_blocks)

    def can_admit(self, n_blocks: int) -> bool:
        """True iff ``n_blocks`` can be guaranteed on top of every admitted
        slot's outstanding reservation (so admission never overcommits).
        Closed under quarantine pressure: see ``headroom``."""
        if n_blocks > self.blocks_per_slot:
            return False
        return n_blocks <= self.headroom

    def admit(self, slot: int, n_blocks: int) -> None:
        """Reserve ``n_blocks`` of worst-case demand for ``slot``. Blocks are
        mapped lazily by ``ensure`` (alloc-on-write)."""
        if self.n_mapped[slot] or self._reserved[slot]:
            raise ValueError(f"slot {slot} already holds blocks; release first")
        if not self.can_admit(n_blocks):
            raise PoolExhausted(
                f"cannot admit {n_blocks} blocks: {self.free_blocks} free, "
                f"{self.reserved_blocks} reserved"
            )
        self._reserved[slot] = n_blocks

    # -- alloc-on-write ------------------------------------------------------
    def ensure(self, slot: int, last_row: int) -> bool:
        """Map blocks so logical rows ``[0, last_row]`` of ``slot`` are
        backed; returns True when the table changed. Mapping consumes the
        slot's reservation first."""
        need = last_row // self.block_size + 1
        if need > self.blocks_per_slot:
            raise ValueError(
                f"row {last_row} needs {need} blocks > blocks_per_slot "
                f"{self.blocks_per_slot}"
            )
        changed = False
        while self.n_mapped[slot] < need:
            if not self._free:
                raise PoolExhausted(
                    f"pool exhausted mapping block {self.n_mapped[slot]} of "
                    f"slot {slot} (admission bypassed?)"
                )
            bid = self._free.pop()
            self.table[slot, self.n_mapped[slot]] = bid
            self.refcount[bid] = 1
            self.n_mapped[slot] += 1
            if self._reserved[slot] > 0:
                self._reserved[slot] -= 1
            changed = True
        return changed

    # -- prefix sharing ------------------------------------------------------
    def map_prefix(self, slot: int, block_ids: list[int]) -> None:
        """Map already-resident blocks at the *front* of ``slot``'s table,
        read-only shared: each gets a refcount bump, none leaves the owning
        tables, and nothing is taken from the free list or the slot's
        reservation. Must run on an empty slot, before any ``ensure`` — the
        shared prefix is logical blocks ``0..len(block_ids)-1`` and private
        alloc-on-write continues from there."""
        if self.n_mapped[slot]:
            raise ValueError(f"slot {slot} already holds blocks; map_prefix "
                             "must precede alloc-on-write")
        if len(block_ids) > self.blocks_per_slot:
            raise ValueError(f"{len(block_ids)} shared blocks > "
                             f"blocks_per_slot {self.blocks_per_slot}")
        for j, bid in enumerate(block_ids):
            if self.refcount[bid] < 1:
                raise ValueError(f"block {bid} is not resident (refcount 0); "
                                 "stale prefix-index entry?")
            self.table[slot, j] = bid
            self.refcount[bid] += 1
        self.n_mapped[slot] = len(block_ids)

    def cow(self, slot: int, logical: int) -> tuple[int, int]:
        """Copy-on-write split: give ``slot`` a private copy of its shared
        logical block ``logical`` before a scatter touches it. Pops a free
        block (consuming the slot's reservation — shared admission reserves
        one extra block when the first write lands inside the shared prefix),
        swaps the table entry, and drops the old block's refcount — the other
        holders keep reading it unchanged. Returns ``(old_bid, new_bid)`` so
        the server can copy the device rows before the next fused step."""
        old = int(self.table[slot, logical])
        if old < 0 or self.refcount[old] < 2:
            raise ValueError(f"slot {slot} logical block {logical} is not "
                             "shared; cow() is only for refcount > 1")
        if not self._free:
            raise PoolExhausted(
                f"pool exhausted COW-splitting block {logical} of slot {slot}"
            )
        new = self._free.pop()
        self.table[slot, logical] = new
        self.refcount[new] = 1
        self.refcount[old] -= 1
        if self._reserved[slot] > 0:
            self._reserved[slot] -= 1
        return old, new

    # -- free-on-finish ------------------------------------------------------
    def release(self, slot: int) -> int:
        """Drop ``slot``'s claim on its blocks and its reservation. Each
        block's refcount is decremented; a block returns to the free list
        only at zero (another slot sharing it keeps it resident — the old
        unconditional append was a double-free under sharing). Returns how
        many blocks actually went back to the free list."""
        n = int(self.n_mapped[slot])
        freed = 0
        for i in range(n):
            bid = int(self.table[slot, i])
            self.refcount[bid] -= 1
            if self.refcount[bid] == 0:
                self._free.append(bid)
                freed += 1
                if self.on_zero is not None:
                    self.on_zero(bid)
        self.table[slot] = -1
        self.n_mapped[slot] = 0
        self._reserved[slot] = 0
        return freed

    # -- views / invariants --------------------------------------------------
    def table_array(self) -> np.ndarray:
        """Device-shippable copy of the block table with unmapped entries
        clamped to block 0: jax gathers wrap negative indices, and a ``-1``
        would silently read the *last* block. Reads through clamped entries
        are masked out by the validity masks; writes are gated by the
        write-ok sentinel."""
        return np.maximum(self.table, 0).astype(np.int32)

    def check(self) -> None:
        """Assert the allocator invariants (test hook / ``debug_checks``):
        distinct-mapped + free + quarantined == total, every block's refcount
        equals the number of table entries mapping it, zero-refcount blocks
        sit on exactly one of the free/quarantine lists (and refcounted
        blocks on neither — a freed shared block would be a double-free),
        mapped entries form a contiguous prefix of each table row, and
        reservations never exceed free + quarantined capacity. The
        reservation bound counts quarantined blocks on purpose: a fault-plan
        ``shrink`` may push ``reserved`` above ``free`` transiently (that is
        the injected pressure the server must preempt its way out of), but
        admission itself never promises more than the pool ever held."""
        mapped = [int(b) for row in self.table for b in row if b >= 0]
        counts = np.bincount(mapped, minlength=self.num_blocks) if mapped \
            else np.zeros(self.num_blocks, np.int64)
        assert (counts == self.refcount).all(), (
            f"refcount drift: table maps {counts.tolist()} but refcount is "
            f"{self.refcount.tolist()}"
        )
        distinct = set(mapped)
        q = len(self._quarantined)
        assert len(distinct) + len(self._free) + q == self.num_blocks, (
            f"conservation broken: {len(distinct)} distinct mapped + "
            f"{len(self._free)} free + {q} quarantined != {self.num_blocks}"
        )
        idle = [int(b) for b in self._free] + \
            [int(b) for b in self._quarantined]
        assert len(set(idle)) == len(idle), (
            "block id on a free/quarantine list twice (double-free)"
        )
        assert not distinct.intersection(idle), (
            "refcounted block on a free/quarantine list (use-after-free)"
        )
        for s in range(self.slots):
            n = int(self.n_mapped[s])
            assert (self.table[s, :n] >= 0).all() and (
                self.table[s, n:] == -1
            ).all(), f"slot {s} table not a contiguous mapped prefix"
        assert self.reserved_blocks <= self.free_blocks + q, (
            f"reservations {self.reserved_blocks} exceed free "
            f"{self.free_blocks} + quarantined {q}: admission overcommitted"
        )


# ---------------------------------------------------------------------------
# Serving-side composite: full-width pool + optional SWA ring pool
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PagedKV:
    """Block pools + table bookkeeping for one ``BatchedServer``.

    ``pool`` backs the full-width cache regions (GQA K/V, MLA latent): logical
    rows are token positions ``0..max_seq-1``. ``ring`` (models with
    sliding-window layers only) backs the SWA ring regions: logical rows are
    ring positions ``pos % ring_width`` — a bounded region, sized per slot.

    With ``prefix_cache=True`` the full pool additionally feeds a
    ``PrefixIndex``: fully-written feed blocks register their content keys,
    ``admit_shared`` maps a matching resident chain with a refcount bump and
    returns the first position the new request actually has to compute, and
    ``cow_step`` splits shared blocks ahead of any write. Incompatible with a
    ring pool (ring rows wrap: a shared ring block would be missing the
    skipped positions' window writes), so the server only enables it for
    attention-only families.
    """

    block_size: int
    max_seq: int
    pool: KVBlockPool
    ring_width: int = 0
    ring: KVBlockPool | None = None
    prefix_cache: bool = False
    index: PrefixIndex | None = dataclasses.field(default=None, init=False)

    def __post_init__(self) -> None:
        if self.prefix_cache:
            if self.ring is not None:
                raise ValueError(
                    "prefix_cache is unsound with a SWA ring pool: ring rows "
                    "wrap, so a sharer skipping prefill would be missing the "
                    "skipped positions' ring writes"
                )
            self.index = PrefixIndex()
            self.pool.on_zero = self.index.evict_block

    @classmethod
    def for_model(cls, cfg: ModelConfig, slots: int, max_seq: int,
                  block_size: int, kv_blocks: int | None = None,
                  prefix_cache: bool = False) -> "PagedKV":
        """Build pools sized for ``cfg``. ``kv_blocks`` caps the full-region
        pool (default: ``slots * ceil(max_seq/block_size)``, i.e. dense-
        equivalent capacity — pass less to oversubscribe slots against a
        fixed memory budget). The ring pool is always fully provisioned: the
        window bounds it, so it is not the memory lever."""
        from repro.models.transformer import segments_for

        if cfg.family in ("encdec", "ssm"):
            raise ValueError(
                f"family {cfg.family!r} has no paged attention cache "
                "(recurrent/enc-dec state is per-slot, not per-token)"
            )
        per_slot = blocks_for(max_seq, block_size)
        num_blocks = slots * per_slot if kv_blocks is None else int(kv_blocks)
        pool = KVBlockPool(num_blocks, block_size, slots, per_slot)
        ring_width, ring = 0, None
        if any(s.kind == "hybrid_swa" for s in segments_for(cfg)):
            ring_width = min(cfg.swa_window, max_seq)
            ring_per_slot = blocks_for(ring_width, block_size)
            ring = KVBlockPool(slots * ring_per_slot, block_size, slots,
                               ring_per_slot)
        return cls(block_size=block_size, max_seq=max_seq, pool=pool,
                   ring_width=ring_width, ring=ring,
                   prefix_cache=prefix_cache)

    # -- request lifetime ----------------------------------------------------
    def required(self, prompt_len: int, max_new: int, chunk: int = 1,
                 token_step: bool = False) -> tuple[int, int]:
        """Worst-case (full, ring) block demand of a request: it writes
        ``min(max_seq, prompt_len + max_new - 1)`` positions (prefill-as-
        decode: the first generation lands on the final prompt step),
        rounded up to the chunk boundary when the server steps ``chunk``
        uniform tokens at a time (the host retires a slot at step end, so the
        last chunk may overshoot by up to ``chunk - 1`` discarded positions).
        Token-level stepping (``token_step=True``) schedules exactly the
        tokens a request needs — prefill rows are capped at the prompt end
        and decode emits one token per step — so no chunk rounding applies
        and the reservation is exactly the written positions."""
        positions = self._end_positions(0, prompt_len, max_new, chunk,
                                        token_step)
        full = blocks_for(positions, self.block_size)
        ring = blocks_for(min(self.ring_width, positions), self.block_size) \
            if self.ring is not None else 0
        return full, ring

    def _end_positions(self, start: int, prompt_len: int, max_new: int,
                       chunk: int, token_step: bool) -> int:
        """Worst-case written horizon of a request stepping from ``start``:
        chunk rounding counts from ``start`` (the server advances the slot in
        ``chunk`` increments from wherever prefill begins), and the floor is
        one step's writes past ``start`` — an admitted slot always runs at
        least one chunk, so a degenerate request must not slip in with a
        zero reservation and then steal blocks."""
        positions = prompt_len + max_new - 1
        if not token_step:
            positions = start + -(-(positions - start) // chunk) * chunk
        floor = start + (1 if token_step else min(chunk, self.max_seq - start))
        return min(self.max_seq, max(positions, floor))

    def plan_shared(self, keys: list[tuple], prompt_len: int, max_new: int,
                    chunk: int = 1, token_step: bool = False
                    ) -> tuple[list[int], int, int]:
        """Shared-admission plan for a request whose full prompt blocks hash
        to ``keys``: ``(shared_block_ids, start, reserve)``.

        ``start`` is the first position the request computes itself,
        ``min(shared_tokens, prompt_len - 1)`` — the *final* prompt position
        is always recomputed so the first-token emission (and greedy
        sampling) runs through the normal step path. ``reserve`` is the
        worst-case demand net of the shared blocks, plus one extra when
        ``start`` lands *inside* the shared prefix: that first write must
        COW-split the block it touches (the split consumes the reservation).
        Sharing never reserves more than the unshared ``required``."""
        hits = self.index.lookup(keys) if self.index is not None else []
        k = len(hits)
        start = min(k * self.block_size, prompt_len - 1)
        end = self._end_positions(start, prompt_len, max_new, chunk,
                                  token_step)
        total = blocks_for(end, self.block_size)
        reserve = total - k + (1 if start < k * self.block_size else 0)
        return hits, start, reserve

    def can_admit_shared(self, keys: list[tuple], prompt_len: int,
                         max_new: int, chunk: int = 1,
                         token_step: bool = False) -> bool:
        if self.index is None:
            return self.can_admit(prompt_len, max_new, chunk, token_step)
        _, _, reserve = self.plan_shared(keys, prompt_len, max_new, chunk,
                                         token_step)
        return self.pool.can_admit(reserve)

    def admit_shared(self, slot: int, keys: list[tuple], prompt_len: int,
                     max_new: int, chunk: int = 1, token_step: bool = False
                     ) -> tuple[int, int]:
        """Admit ``slot`` mapping the longest resident prefix chain shared;
        returns ``(start, n_shared_blocks)``. Falls back to a plain unshared
        admission (``start=0``) when the prefix cache is off."""
        if self.index is None:
            self.admit(slot, prompt_len, max_new, chunk, token_step)
            return 0, 0
        hits, start, reserve = self.plan_shared(keys, prompt_len, max_new,
                                                chunk, token_step)
        self.pool.admit(slot, reserve)
        self.pool.map_prefix(slot, hits)
        return start, len(hits)

    def cow_step(self, slot: int, pos: int, n_tokens: int,
                 out: list | None = None) -> list[tuple[int, int]]:
        """Copy-on-write for one fused step: split every *shared* block
        covering the rows ``pos .. pos+n_tokens-1`` that ``slot`` is about to
        write. Appends ``(old_bid, new_bid)`` pairs to ``out`` (so a caller
        looping ensure-or-preempt keeps the pairs already split when a later
        split raises ``PoolExhausted``) and returns it; the server must copy
        those device rows before the step's scatter runs."""
        pairs = out if out is not None else []
        if self.index is None:
            return pairs
        last = min(pos + n_tokens - 1, self.max_seq - 1)
        for j in range(pos // self.block_size, last // self.block_size + 1):
            if j >= int(self.pool.n_mapped[slot]):
                break
            bid = int(self.pool.table[slot, j])
            if int(self.pool.refcount[bid]) > 1:
                pairs.append(self.pool.cow(slot, j))
        return pairs

    def register_blocks(self, slot: int, keys: list[tuple], j0: int,
                        j1: int) -> int:
        """Register ``slot``'s fully-written feed blocks ``j0..j1-1`` in the
        prefix index (first writer wins; re-registering a key or an indexed
        block is a no-op). Returns ``j1`` as the caller's new watermark."""
        if self.index is not None:
            for j in range(j0, min(j1, len(keys))):
                self.index.register(keys[j], int(self.pool.table[slot, j]))
        return j1

    def can_admit(self, prompt_len: int, max_new: int, chunk: int = 1,
                  token_step: bool = False) -> bool:
        full, ring = self.required(prompt_len, max_new, chunk, token_step)
        if not self.pool.can_admit(full):
            return False
        return self.ring is None or self.ring.can_admit(ring)

    def admit(self, slot: int, prompt_len: int, max_new: int,
              chunk: int = 1, token_step: bool = False) -> None:
        full, ring = self.required(prompt_len, max_new, chunk, token_step)
        self.pool.admit(slot, full)
        if self.ring is not None:
            self.ring.admit(slot, ring)

    def release(self, slot: int) -> int:
        n = self.pool.release(slot)
        if self.ring is not None:
            n += self.ring.release(slot)
        return n

    def ensure_step(self, slot: int, pos: int, n_tokens: int) -> bool:
        """Alloc-on-write for one fused step: map blocks covering the rows
        this slot will write — positions ``pos .. pos+n_tokens-1`` in the
        full region, their ring images in the ring region."""
        last = min(pos + n_tokens - 1, self.max_seq - 1)
        changed = self.pool.ensure(slot, last)
        if self.ring is not None:
            changed |= self.ring.ensure(slot, min(last, self.ring_width - 1))
        return changed

    def shrink(self, n: int) -> int:
        """Fault injection: quarantine up to ``n`` blocks from the full-width
        pool (the memory lever; the SWA ring is window-bounded and stays
        fully provisioned — shrinking it would break ring semantics, not
        model memory pressure)."""
        return self.pool.shrink(n)

    def grow(self, n: int | None = None) -> int:
        return self.pool.grow(n)

    def check(self) -> None:
        """Assert both pools' allocator invariants (``debug_checks`` hook),
        plus the prefix-index lifecycle: an indexed block is always resident
        (refcount >= 1 — eviction-on-zero must never lag a free)."""
        self.pool.check()
        if self.ring is not None:
            self.ring.check()
        if self.index is not None:
            for bid in self.index.blocks():
                assert int(self.pool.refcount[bid]) >= 1, (
                    f"prefix index holds freed block {bid}"
                )

    def tables(self) -> tuple[np.ndarray, np.ndarray | None]:
        return (self.pool.table_array(),
                self.ring.table_array() if self.ring is not None else None)

    def token_tables(self, slot_ids) -> tuple[np.ndarray, np.ndarray | None]:
        """Per-token block tables for a flattened token batch: row ``i`` is
        the table of the slot token ``i`` maps to (what the paged-attention
        kernel scalar-prefetches). ``slot_ids`` is any int sequence; padding
        tokens may point at any live slot — their reads are masked and their
        writes are gated off by ``write_ok``."""
        ids = np.asarray(slot_ids, np.int32)
        full = self.pool.table_array()[ids]
        ring = self.ring.table_array()[ids] if self.ring is not None else None
        return full, ring
