"""Paged KV-cache block pool: fixed-size token blocks behind the serving engine.

DAnA's Striders replace dense hand-routed buffer access with an access engine
that walks page layouts directly (PAPER.md §Striders); the serving analogue is
vLLM-style paged attention. Instead of every decode slot owning a dense
``max_seq`` cache row — memory scaling with the *worst case* sequence — the
cache is a pool of fixed-size token blocks:

  * ``KVBlockPool`` — the allocator. A free list of physical block ids, a
    per-slot block table (logical block index -> physical block id),
    alloc-on-write (a block is mapped the first time a token position inside
    it is written), free-on-finish (a finished request returns its blocks),
    and reservation-based admission: a request is admitted only when the pool
    can cover its worst-case block demand, so a running request can never hit
    pool exhaustion mid-flight — OOM surfaces as *deferred admission*, never
    as a crash. Invariants (``free + in_use == total``, no double allocation,
    table/length consistency) are pinned by ``tests/test_kv_pool.py``.
  * ``PagedKV`` — the serving-side composite: one pool for the full-width
    cache regions (GQA K/V, MLA latent) and, for models with sliding-window
    layers, a second pool whose logical rows are *ring* positions
    (``pos % ring_width``), so SWA ring semantics map onto blocks with the
    same validity story as the dense ring.

The device-side layout lives in ``models/attention.py``
(``gqa_decode_paged`` / ``mla_decode_paged``): cache leaves are block pools
``(num_blocks, block_size, ...)`` shared by every slot, and decode gathers a
slot's K/V through its block-table row. The pool here is pure host-side
bookkeeping (numpy) — the tables ship to the device as tiny int32 arrays.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


class PoolExhausted(RuntimeError):
    """A block was demanded that the free list cannot supply. Never raised
    when admission goes through ``can_admit``/``admit`` (reservations cover
    the worst case); reaching it means the admission protocol was bypassed."""


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to cover ``n_tokens`` token rows (ceil division)."""
    return -(-max(0, n_tokens) // block_size)


class KVBlockPool:
    """Fixed-size token-block allocator with a free list, per-slot block
    tables, alloc-on-write and reservation-based admission.

    Logical rows (cache row indices: token positions for full regions, ring
    positions for SWA regions) map onto logical block indices ``row //
    block_size``; the table maps those to physical block ids. Unmapped table
    entries hold ``-1``.
    """

    def __init__(self, num_blocks: int, block_size: int, slots: int,
                 blocks_per_slot: int):
        if num_blocks < 0 or block_size < 1 or slots < 1 or blocks_per_slot < 1:
            raise ValueError(
                f"bad pool shape: num_blocks={num_blocks} "
                f"block_size={block_size} slots={slots} "
                f"blocks_per_slot={blocks_per_slot}"
            )
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.slots = int(slots)
        self.blocks_per_slot = int(blocks_per_slot)
        self.table = np.full((slots, blocks_per_slot), -1, np.int32)
        self.n_mapped = np.zeros(slots, np.int32)
        # LIFO free list: recycled blocks are re-mapped first, which is what
        # the parity tests lean on to prove stale contents are harmless
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._reserved = np.zeros(slots, np.int64)
        # fault-injection quarantine (serve/faults.py): blocks pulled out of
        # the free list by `shrink`, invisible to allocation until `grow`
        self._quarantined: list[int] = []

    # -- accounting ----------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        """Blocks mapped into slot tables (quarantined blocks are withheld
        by a fault plan, not in use — they must not inflate the peak-usage
        metric or read as a leak after a drain)."""
        return self.num_blocks - len(self._free) - len(self._quarantined)

    @property
    def reserved_blocks(self) -> int:
        """Outstanding worst-case demand of admitted slots not yet mapped."""
        return int(self._reserved.sum())

    @property
    def quarantined_blocks(self) -> int:
        """Blocks a fault plan has shrunk out of the pool (0 normally)."""
        return len(self._quarantined)

    # -- fault injection -----------------------------------------------------
    def shrink(self, n: int) -> int:
        """Quarantine up to ``n`` free blocks (fault injection: capacity
        vanishes out from under outstanding reservations, so a later
        ``ensure`` may raise ``PoolExhausted`` mid-run — the *server's*
        preemption path, not this class, restores the admission invariant).
        Returns how many blocks were actually quarantined."""
        take = min(int(n), len(self._free))
        for _ in range(take):
            self._quarantined.append(self._free.pop())
        return take

    def grow(self, n: int | None = None) -> int:
        """Return up to ``n`` quarantined blocks (all when None) to the free
        list; returns how many came back."""
        back = len(self._quarantined) if n is None else min(int(n),
                                                            len(self._quarantined))
        for _ in range(back):
            self._free.append(self._quarantined.pop())
        return back

    # -- admission -----------------------------------------------------------
    def can_admit(self, n_blocks: int) -> bool:
        """True iff ``n_blocks`` can be guaranteed on top of every admitted
        slot's outstanding reservation (so admission never overcommits)."""
        if n_blocks > self.blocks_per_slot:
            return False
        return n_blocks <= self.free_blocks - self.reserved_blocks

    def admit(self, slot: int, n_blocks: int) -> None:
        """Reserve ``n_blocks`` of worst-case demand for ``slot``. Blocks are
        mapped lazily by ``ensure`` (alloc-on-write)."""
        if self.n_mapped[slot] or self._reserved[slot]:
            raise ValueError(f"slot {slot} already holds blocks; release first")
        if not self.can_admit(n_blocks):
            raise PoolExhausted(
                f"cannot admit {n_blocks} blocks: {self.free_blocks} free, "
                f"{self.reserved_blocks} reserved"
            )
        self._reserved[slot] = n_blocks

    # -- alloc-on-write ------------------------------------------------------
    def ensure(self, slot: int, last_row: int) -> bool:
        """Map blocks so logical rows ``[0, last_row]`` of ``slot`` are
        backed; returns True when the table changed. Mapping consumes the
        slot's reservation first."""
        need = last_row // self.block_size + 1
        if need > self.blocks_per_slot:
            raise ValueError(
                f"row {last_row} needs {need} blocks > blocks_per_slot "
                f"{self.blocks_per_slot}"
            )
        changed = False
        while self.n_mapped[slot] < need:
            if not self._free:
                raise PoolExhausted(
                    f"pool exhausted mapping block {self.n_mapped[slot]} of "
                    f"slot {slot} (admission bypassed?)"
                )
            bid = self._free.pop()
            self.table[slot, self.n_mapped[slot]] = bid
            self.n_mapped[slot] += 1
            if self._reserved[slot] > 0:
                self._reserved[slot] -= 1
            changed = True
        return changed

    # -- free-on-finish ------------------------------------------------------
    def release(self, slot: int) -> int:
        """Return ``slot``'s blocks to the free list and drop its
        reservation; returns how many blocks were freed."""
        n = int(self.n_mapped[slot])
        for i in range(n):
            self._free.append(int(self.table[slot, i]))
        self.table[slot] = -1
        self.n_mapped[slot] = 0
        self._reserved[slot] = 0
        return n

    # -- views / invariants --------------------------------------------------
    def table_array(self) -> np.ndarray:
        """Device-shippable copy of the block table with unmapped entries
        clamped to block 0: jax gathers wrap negative indices, and a ``-1``
        would silently read the *last* block. Reads through clamped entries
        are masked out by the validity masks; writes are gated by the
        write-ok sentinel."""
        return np.maximum(self.table, 0).astype(np.int32)

    def check(self) -> None:
        """Assert the allocator invariants (test hook / ``debug_checks``):
        free + in_use + quarantined == total, no block id appears twice
        (across tables, the free list, and the quarantine), mapped entries
        form a contiguous prefix of each table row, and reservations never
        exceed free + quarantined capacity. The reservation bound counts
        quarantined blocks on purpose: a fault-plan ``shrink`` may push
        ``reserved`` above ``free`` transiently (that is the injected
        pressure the server must preempt its way out of), but admission
        itself never promises more than the pool ever held."""
        mapped = [int(b) for row in self.table for b in row if b >= 0]
        q = len(self._quarantined)
        assert len(mapped) + len(self._free) + q == self.num_blocks, (
            f"conservation broken: {len(mapped)} mapped + "
            f"{len(self._free)} free + {q} quarantined != {self.num_blocks}"
        )
        seen = mapped + [int(b) for b in self._free] + \
            [int(b) for b in self._quarantined]
        assert len(set(seen)) == len(seen), "block id allocated twice"
        for s in range(self.slots):
            n = int(self.n_mapped[s])
            assert (self.table[s, :n] >= 0).all() and (
                self.table[s, n:] == -1
            ).all(), f"slot {s} table not a contiguous mapped prefix"
        assert self.reserved_blocks <= self.free_blocks + q, (
            f"reservations {self.reserved_blocks} exceed free "
            f"{self.free_blocks} + quarantined {q}: admission overcommitted"
        )


# ---------------------------------------------------------------------------
# Serving-side composite: full-width pool + optional SWA ring pool
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PagedKV:
    """Block pools + table bookkeeping for one ``BatchedServer``.

    ``pool`` backs the full-width cache regions (GQA K/V, MLA latent): logical
    rows are token positions ``0..max_seq-1``. ``ring`` (models with
    sliding-window layers only) backs the SWA ring regions: logical rows are
    ring positions ``pos % ring_width`` — a bounded region, sized per slot.
    """

    block_size: int
    max_seq: int
    pool: KVBlockPool
    ring_width: int = 0
    ring: KVBlockPool | None = None

    @classmethod
    def for_model(cls, cfg: ModelConfig, slots: int, max_seq: int,
                  block_size: int, kv_blocks: int | None = None) -> "PagedKV":
        """Build pools sized for ``cfg``. ``kv_blocks`` caps the full-region
        pool (default: ``slots * ceil(max_seq/block_size)``, i.e. dense-
        equivalent capacity — pass less to oversubscribe slots against a
        fixed memory budget). The ring pool is always fully provisioned: the
        window bounds it, so it is not the memory lever."""
        from repro.models.transformer import segments_for

        if cfg.family in ("encdec", "ssm"):
            raise ValueError(
                f"family {cfg.family!r} has no paged attention cache "
                "(recurrent/enc-dec state is per-slot, not per-token)"
            )
        per_slot = blocks_for(max_seq, block_size)
        num_blocks = slots * per_slot if kv_blocks is None else int(kv_blocks)
        pool = KVBlockPool(num_blocks, block_size, slots, per_slot)
        ring_width, ring = 0, None
        if any(s.kind == "hybrid_swa" for s in segments_for(cfg)):
            ring_width = min(cfg.swa_window, max_seq)
            ring_per_slot = blocks_for(ring_width, block_size)
            ring = KVBlockPool(slots * ring_per_slot, block_size, slots,
                               ring_per_slot)
        return cls(block_size=block_size, max_seq=max_seq, pool=pool,
                   ring_width=ring_width, ring=ring)

    # -- request lifetime ----------------------------------------------------
    def required(self, prompt_len: int, max_new: int, chunk: int = 1,
                 token_step: bool = False) -> tuple[int, int]:
        """Worst-case (full, ring) block demand of a request: it writes
        ``min(max_seq, prompt_len + max_new - 1)`` positions (prefill-as-
        decode: the first generation lands on the final prompt step),
        rounded up to the chunk boundary when the server steps ``chunk``
        uniform tokens at a time (the host retires a slot at step end, so the
        last chunk may overshoot by up to ``chunk - 1`` discarded positions).
        Token-level stepping (``token_step=True``) schedules exactly the
        tokens a request needs — prefill rows are capped at the prompt end
        and decode emits one token per step — so no chunk rounding applies
        and the reservation is exactly the written positions."""
        positions = prompt_len + max_new - 1
        if not token_step:
            positions = -(-positions // chunk) * chunk
        # never reserve less than one step's writes: the engine always runs
        # at least one chunk for an admitted slot, so a degenerate request
        # must not slip in with a zero reservation and then steal blocks
        floor = 1 if token_step else min(chunk, self.max_seq)
        positions = min(self.max_seq, max(positions, floor))
        full = blocks_for(positions, self.block_size)
        ring = blocks_for(min(self.ring_width, positions), self.block_size) \
            if self.ring is not None else 0
        return full, ring

    def can_admit(self, prompt_len: int, max_new: int, chunk: int = 1,
                  token_step: bool = False) -> bool:
        full, ring = self.required(prompt_len, max_new, chunk, token_step)
        if not self.pool.can_admit(full):
            return False
        return self.ring is None or self.ring.can_admit(ring)

    def admit(self, slot: int, prompt_len: int, max_new: int,
              chunk: int = 1, token_step: bool = False) -> None:
        full, ring = self.required(prompt_len, max_new, chunk, token_step)
        self.pool.admit(slot, full)
        if self.ring is not None:
            self.ring.admit(slot, ring)

    def release(self, slot: int) -> int:
        n = self.pool.release(slot)
        if self.ring is not None:
            n += self.ring.release(slot)
        return n

    def ensure_step(self, slot: int, pos: int, n_tokens: int) -> bool:
        """Alloc-on-write for one fused step: map blocks covering the rows
        this slot will write — positions ``pos .. pos+n_tokens-1`` in the
        full region, their ring images in the ring region."""
        last = min(pos + n_tokens - 1, self.max_seq - 1)
        changed = self.pool.ensure(slot, last)
        if self.ring is not None:
            changed |= self.ring.ensure(slot, min(last, self.ring_width - 1))
        return changed

    def shrink(self, n: int) -> int:
        """Fault injection: quarantine up to ``n`` blocks from the full-width
        pool (the memory lever; the SWA ring is window-bounded and stays
        fully provisioned — shrinking it would break ring semantics, not
        model memory pressure)."""
        return self.pool.shrink(n)

    def grow(self, n: int | None = None) -> int:
        return self.pool.grow(n)

    def check(self) -> None:
        """Assert both pools' allocator invariants (``debug_checks`` hook)."""
        self.pool.check()
        if self.ring is not None:
            self.ring.check()

    def tables(self) -> tuple[np.ndarray, np.ndarray | None]:
        return (self.pool.table_array(),
                self.ring.table_array() if self.ring is not None else None)

    def token_tables(self, slot_ids) -> tuple[np.ndarray, np.ndarray | None]:
        """Per-token block tables for a flattened token batch: row ``i`` is
        the table of the slot token ``i`` maps to (what the paged-attention
        kernel scalar-prefetches). ``slot_ids`` is any int sequence; padding
        tokens may point at any live slot — their reads are masked and their
        writes are gated off by ``write_ok``."""
        ids = np.asarray(slot_ids, np.int32)
        full = self.pool.table_array()[ids]
        ring = self.ring.table_array()[ids] if self.ring is not None else None
        return full, ring
