"""Seeded fault injection + synthetic production traces for the serving engine.

Nothing in a green test suite proves the engine survives the conditions the
robustness machinery exists for — pool pressure mid-decode, forced evictions,
a stalled admission path, deadline storms. ``FaultPlan`` scripts those
conditions as *deterministic, seeded* schedules the server applies at chosen
steps, so every chaos failure is a replayable unit test, not a flake:

  * ``shrink_pool n`` — quarantine up to ``n`` blocks out of the paged pool's
    free list (``KVBlockPool.shrink``). Capacity vanishes out from under
    outstanding reservations, so a later ``ensure_step`` can hit
    ``PoolExhausted`` mid-run — exercising the server's preempt-on-pressure
    path. No-op on dense servers.
  * ``grow_pool n`` — return quarantined blocks.
  * ``force_preempt k`` — evict up to ``k`` victims via the server's victim
    policy regardless of priority (``pick_victim(below=None)``): the
    recompute-on-resume path under fire.
  * ``stall_admission k`` — admission skipped for the next ``k`` steps
    (deadline sweeps keep running): head-of-line pressure without pool
    involvement.
  * ``advance_clock dt`` — tick the plan's ``VirtualClock`` by ``dt``
    seconds. A plan that carries clock events owns the server's clock, so
    deadline pressure fires at *chosen steps* instead of wherever a real
    runner's wall clock happens to land.

Every plan **heals**: at ``heal_step`` (default: one past the last event) all
quarantined blocks return and stalls clear, so a bounded ``run(max_steps=)``
always drains — the chaos suite's termination guarantee. ``applied`` logs
each event's observed effect for debugging a failing seed.

The second half of this module is the **production-trace harness** the
``serve_prefix`` bench and the fairness tests measure against. Real serving
traffic is not what ad-hoc test loops generate: arrivals are bursty per
tenant, lengths are heavy-tailed, and most prompts open with one of a few
shared templates (system prompts, few-shot preambles — the structure the
prefix cache exists to exploit). ``synth_trace`` generates exactly that shape
from a seed:

  * per-tenant Poisson arrivals (requests per server step) with seeded burst
    windows during which the tenant's rate multiplies;
  * heavy-tailed (lognormal, clipped) prompt-suffix and output lengths —
    a few whales among many minnows, the distribution that stresses both
    block budgets and fairness;
  * per-tenant template pools: each request opens with one of the tenant's
    shared prompt templates with probability ``p_shared`` (templates are
    tenant-private — cross-tenant prompts never collide, so sharing wins
    come from *within*-tenant traffic, the realistic case).

``replay_trace`` feeds a trace through a ``BatchedServer`` against the
server's own fused-step clock (``server.step_no``): a request is submitted
the step it "arrives", so two configurations replaying the same seed see the
*identical* offered load — the controlled-experiment property every A/B in
``benchmarks/bench_serve.py`` leans on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

KINDS = ("shrink_pool", "grow_pool", "force_preempt", "stall_admission",
         "advance_clock")


class VirtualClock:
    """Deterministic stand-in for ``time.perf_counter``: returns a manually
    advanced value, so wall-clock deadlines become scriptable."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str
    arg: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.step < 0:
            raise ValueError(f"step must be >= 0, got {self.step}")


class FaultPlan:
    """A replayable fault schedule (see module doc).

    ``clock`` is created automatically when any ``advance_clock`` event is
    present (pass one explicitly to share it with the request generator);
    ``BatchedServer`` adopts it as the server clock when set."""

    def __init__(self, events: list[FaultEvent], heal_step: int | None = None,
                 clock: VirtualClock | None = None):
        self.events = sorted(events, key=lambda e: (e.step, KINDS.index(e.kind)))
        last = max((e.step for e in self.events), default=-1)
        self.heal_step = last + 1 if heal_step is None else int(heal_step)
        if self.heal_step <= last:
            raise ValueError(
                f"heal_step {self.heal_step} must come after the last "
                f"event (step {last}): an unhealed plan can wedge the server"
            )
        if clock is None and any(e.kind == "advance_clock" for e in self.events):
            clock = VirtualClock()
        self.clock = clock
        self.applied: list[tuple[int, str, float, float]] = []
        self._healed = False

    @classmethod
    def random(cls, seed: int, horizon: int = 24, *,
               p_shrink: float = 0.18, p_grow: float = 0.10,
               p_preempt: float = 0.15, p_stall: float = 0.10,
               p_clock: float = 0.35, max_blocks: int = 4,
               clock: VirtualClock | None = None) -> "FaultPlan":
        """Seeded-random schedule over ``horizon`` steps; identical seed =
        identical chaos, which is what makes a chaos failure debuggable."""
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        for step in range(horizon):
            if rng.random() < p_shrink:
                events.append(FaultEvent(step, "shrink_pool",
                                         int(rng.integers(1, max_blocks + 1))))
            if rng.random() < p_grow:
                events.append(FaultEvent(step, "grow_pool",
                                         int(rng.integers(1, max_blocks + 1))))
            if rng.random() < p_preempt:
                events.append(FaultEvent(step, "force_preempt",
                                         int(rng.integers(1, 3))))
            if rng.random() < p_stall:
                events.append(FaultEvent(step, "stall_admission",
                                         int(rng.integers(1, 4))))
            if rng.random() < p_clock:
                events.append(FaultEvent(step, "advance_clock",
                                         float(rng.uniform(0.05, 0.6))))
        return cls(events, heal_step=horizon, clock=clock)

    def events_at(self, step: int) -> list[FaultEvent]:
        return [e for e in self.events if e.step == step]

    def apply(self, server, step: int) -> None:
        """Apply this plan's events for ``step`` to ``server`` (called at the
        top of ``BatchedServer.step``). Idempotent healing at ``heal_step``."""
        from repro.serve import scheduler as sched

        for ev in self.events_at(step):
            effect = 0.0
            if ev.kind == "shrink_pool":
                if server._paged is not None:
                    effect = server._paged.shrink(int(ev.arg))
            elif ev.kind == "grow_pool":
                if server._paged is not None:
                    effect = server._paged.grow(int(ev.arg))
            elif ev.kind == "force_preempt":
                for _ in range(int(ev.arg)):
                    victim = sched.pick_victim(server.active, below=None)
                    if victim is None:
                        break
                    server._preempt(victim)
                    effect += 1
            elif ev.kind == "stall_admission":
                server._admit_stall = max(server._admit_stall, int(ev.arg))
                effect = server._admit_stall
            elif ev.kind == "advance_clock":
                if self.clock is not None:
                    self.clock.advance(ev.arg)
                    effect = ev.arg
            self.applied.append((step, ev.kind, float(ev.arg), float(effect)))
        if step >= self.heal_step and not self._healed:
            self._healed = True
            if server._paged is not None:
                healed = server._paged.grow(None)
                self.applied.append((step, "heal", 0.0, float(healed)))
            server._admit_stall = 0


# ---------------------------------------------------------------------------
# Synthetic production traces (see module doc, second half)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One arrival in a synthetic trace: everything ``replay_trace`` needs to
    build a ``serve.serving.Request``. ``template_id`` records which of the
    tenant's shared templates (if any) opens the prompt — analysis metadata,
    not replayed state."""
    rid: int
    arrival_step: int
    tenant: int
    priority: int
    prompt: tuple
    max_new_tokens: int
    template_id: int = -1


@dataclasses.dataclass(frozen=True)
class Trace:
    """A seeded synthetic workload: arrivals sorted by ``arrival_step`` (rid
    order == arrival order), plus the tenant weights the generator assigned —
    hand these to ``BatchedServer(tenant_weights=...)`` so the wdrr scheduler
    competes tenants at the shape the trace was built for."""
    requests: tuple
    tenant_weights: dict
    seed: int

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def tenants(self) -> list:
        return sorted({r.tenant for r in self.requests})

    def shared_fraction(self) -> float:
        """Fraction of requests opening with a shared template."""
        if not self.requests:
            return 0.0
        return sum(r.template_id >= 0 for r in self.requests) / len(self.requests)


def _clipped_lognormal(rng, mean: float, sigma: float, lo: int, hi: int) -> int:
    """Heavy-tailed length draw: lognormal scaled to ``mean``, clipped into
    ``[lo, hi]`` — most draws land well under the mean, a few whales push
    against ``hi`` (the tail the block budget has to survive)."""
    # median = mean / exp(sigma^2/2) keeps the configured mean after the
    # lognormal's tail inflation
    mu = float(np.log(max(mean, 1.0)) - 0.5 * sigma * sigma)
    return int(np.clip(round(float(rng.lognormal(mu, sigma))), lo, hi))


def synth_trace(seed: int, *, steps: int = 48, tenants: int = 3,
                vocab: int = 64, rate: float = 0.25, burst_mult: float = 4.0,
                p_burst: float = 0.12, burst_len: int = 4,
                templates_per_tenant: int = 2, template_len: int = 12,
                p_shared: float = 0.7, mean_suffix: int = 4,
                mean_new: float = 6.0, sigma: float = 0.6,
                max_prompt: int = 32, max_new: int = 16,
                weights: dict | None = None) -> Trace:
    """Generate a seeded synthetic production trace (see module doc).

    Each tenant arrives as an independent Poisson process at ``rate``
    requests per step, multiplied by ``burst_mult`` inside seeded burst
    windows (each step opens a ``burst_len``-step window with probability
    ``p_burst``). A request opens with one of the tenant's
    ``templates_per_tenant`` shared ``template_len``-token templates with
    probability ``p_shared``, followed by a heavy-tailed unique suffix;
    non-template prompts are fully unique. Lengths are clipped lognormals
    (``sigma`` controls the tail). ``weights`` defaults to ``2**t`` — tenant
    0 lightest — so weighted-fairness runs have real shares to enforce.

    Same seed, same kwargs -> identical trace, independent of the server it
    later replays through.
    """
    if tenants < 1 or steps < 1:
        raise ValueError(f"need tenants >= 1 and steps >= 1, got "
                         f"{tenants}, {steps}")
    if template_len >= max_prompt:
        raise ValueError(f"template_len {template_len} must leave room under "
                         f"max_prompt {max_prompt}")
    rng = np.random.default_rng(seed)
    # tenant-private template pools: disjoint across tenants by construction
    # (independent random draws over vocab make cross-tenant collisions
    # astronomically unlikely; prefix keys are exact, so a collision would
    # only merge genuinely identical token blocks anyway)
    pools = [
        [tuple(int(t) for t in rng.integers(0, vocab, template_len))
         for _ in range(templates_per_tenant)]
        for _ in range(tenants)
    ]
    burst_until = [0] * tenants
    reqs: list[TraceRequest] = []
    rid = 0
    for step in range(steps):
        for t in range(tenants):
            if step >= burst_until[t] and rng.random() < p_burst:
                burst_until[t] = step + burst_len
            lam = rate * (burst_mult if step < burst_until[t] else 1.0)
            for _ in range(int(rng.poisson(lam))):
                tid = -1
                head: tuple = ()
                if rng.random() < p_shared:
                    tid = int(rng.integers(0, templates_per_tenant))
                    head = pools[t][tid]
                suffix_room = max_prompt - len(head)
                n_suffix = _clipped_lognormal(rng, mean_suffix, sigma,
                                              1, suffix_room)
                suffix = tuple(int(x) for x in rng.integers(0, vocab, n_suffix))
                n_new = _clipped_lognormal(rng, mean_new, sigma, 1, max_new)
                reqs.append(TraceRequest(
                    rid=rid, arrival_step=step, tenant=t,
                    priority=0, prompt=head + suffix,
                    max_new_tokens=n_new, template_id=tid,
                ))
                rid += 1
    if weights is None:
        weights = {t: float(2 ** t) for t in range(tenants)}
    return Trace(requests=tuple(reqs), tenant_weights=dict(weights),
                 seed=int(seed))


def replay_trace(server, trace: Trace, max_steps: int = 2000,
                 priority: int | None = None) -> list:
    """Replay ``trace`` through ``server`` against its fused-step clock:
    each ``TraceRequest`` is submitted at the step it arrives (arrivals for
    step ``k`` land just before the server takes step ``k``), then the
    server drains. Returns the terminal requests, rid order.

    The request stream is identical for every server configuration replaying
    the same trace — offered load is a property of the trace, admission and
    scheduling decide what happens to it. ``max_steps`` bounds the drain so
    a wedged configuration fails a test instead of hanging it; raises if the
    trace did not drain."""
    from repro.serve.serving import Request

    pending = sorted(trace.requests, key=lambda r: (r.arrival_step, r.rid))
    i = 0
    while i < len(pending) or server.queue or \
            any(r is not None for r in server.active):
        if server.step_no >= max_steps:
            raise RuntimeError(
                f"trace replay did not drain in {max_steps} steps "
                f"({len(pending) - i} arrivals unsubmitted, "
                f"{len(server.queue)} queued)"
            )
        while i < len(pending) and pending[i].arrival_step <= server.step_no:
            tr = pending[i]
            server.submit(Request(
                rid=tr.rid, prompt=list(tr.prompt),
                max_new_tokens=tr.max_new_tokens, tenant=tr.tenant,
                priority=tr.priority if priority is None else priority,
            ))
            i += 1
        server.step()
    return sorted(server.finished, key=lambda r: r.rid)
