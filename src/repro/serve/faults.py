"""Seeded fault injection for the serving engine: chaos you can replay.

Nothing in a green test suite proves the engine survives the conditions the
robustness machinery exists for — pool pressure mid-decode, forced evictions,
a stalled admission path, deadline storms. ``FaultPlan`` scripts those
conditions as *deterministic, seeded* schedules the server applies at chosen
steps, so every chaos failure is a replayable unit test, not a flake:

  * ``shrink_pool n`` — quarantine up to ``n`` blocks out of the paged pool's
    free list (``KVBlockPool.shrink``). Capacity vanishes out from under
    outstanding reservations, so a later ``ensure_step`` can hit
    ``PoolExhausted`` mid-run — exercising the server's preempt-on-pressure
    path. No-op on dense servers.
  * ``grow_pool n`` — return quarantined blocks.
  * ``force_preempt k`` — evict up to ``k`` victims via the server's victim
    policy regardless of priority (``pick_victim(below=None)``): the
    recompute-on-resume path under fire.
  * ``stall_admission k`` — admission skipped for the next ``k`` steps
    (deadline sweeps keep running): head-of-line pressure without pool
    involvement.
  * ``advance_clock dt`` — tick the plan's ``VirtualClock`` by ``dt``
    seconds. A plan that carries clock events owns the server's clock, so
    deadline pressure fires at *chosen steps* instead of wherever a real
    runner's wall clock happens to land.

Every plan **heals**: at ``heal_step`` (default: one past the last event) all
quarantined blocks return and stalls clear, so a bounded ``run(max_steps=)``
always drains — the chaos suite's termination guarantee. ``applied`` logs
each event's observed effect for debugging a failing seed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

KINDS = ("shrink_pool", "grow_pool", "force_preempt", "stall_admission",
         "advance_clock")


class VirtualClock:
    """Deterministic stand-in for ``time.perf_counter``: returns a manually
    advanced value, so wall-clock deadlines become scriptable."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str
    arg: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.step < 0:
            raise ValueError(f"step must be >= 0, got {self.step}")


class FaultPlan:
    """A replayable fault schedule (see module doc).

    ``clock`` is created automatically when any ``advance_clock`` event is
    present (pass one explicitly to share it with the request generator);
    ``BatchedServer`` adopts it as the server clock when set."""

    def __init__(self, events: list[FaultEvent], heal_step: int | None = None,
                 clock: VirtualClock | None = None):
        self.events = sorted(events, key=lambda e: (e.step, KINDS.index(e.kind)))
        last = max((e.step for e in self.events), default=-1)
        self.heal_step = last + 1 if heal_step is None else int(heal_step)
        if self.heal_step <= last:
            raise ValueError(
                f"heal_step {self.heal_step} must come after the last "
                f"event (step {last}): an unhealed plan can wedge the server"
            )
        if clock is None and any(e.kind == "advance_clock" for e in self.events):
            clock = VirtualClock()
        self.clock = clock
        self.applied: list[tuple[int, str, float, float]] = []
        self._healed = False

    @classmethod
    def random(cls, seed: int, horizon: int = 24, *,
               p_shrink: float = 0.18, p_grow: float = 0.10,
               p_preempt: float = 0.15, p_stall: float = 0.10,
               p_clock: float = 0.35, max_blocks: int = 4,
               clock: VirtualClock | None = None) -> "FaultPlan":
        """Seeded-random schedule over ``horizon`` steps; identical seed =
        identical chaos, which is what makes a chaos failure debuggable."""
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        for step in range(horizon):
            if rng.random() < p_shrink:
                events.append(FaultEvent(step, "shrink_pool",
                                         int(rng.integers(1, max_blocks + 1))))
            if rng.random() < p_grow:
                events.append(FaultEvent(step, "grow_pool",
                                         int(rng.integers(1, max_blocks + 1))))
            if rng.random() < p_preempt:
                events.append(FaultEvent(step, "force_preempt",
                                         int(rng.integers(1, 3))))
            if rng.random() < p_stall:
                events.append(FaultEvent(step, "stall_admission",
                                         int(rng.integers(1, 4))))
            if rng.random() < p_clock:
                events.append(FaultEvent(step, "advance_clock",
                                         float(rng.uniform(0.05, 0.6))))
        return cls(events, heal_step=horizon, clock=clock)

    def events_at(self, step: int) -> list[FaultEvent]:
        return [e for e in self.events if e.step == step]

    def apply(self, server, step: int) -> None:
        """Apply this plan's events for ``step`` to ``server`` (called at the
        top of ``BatchedServer.step``). Idempotent healing at ``heal_step``."""
        from repro.serve import scheduler as sched

        for ev in self.events_at(step):
            effect = 0.0
            if ev.kind == "shrink_pool":
                if server._paged is not None:
                    effect = server._paged.shrink(int(ev.arg))
            elif ev.kind == "grow_pool":
                if server._paged is not None:
                    effect = server._paged.grow(int(ev.arg))
            elif ev.kind == "force_preempt":
                for _ in range(int(ev.arg)):
                    victim = sched.pick_victim(server.active, below=None)
                    if victim is None:
                        break
                    server._preempt(victim)
                    effect += 1
            elif ev.kind == "stall_admission":
                server._admit_stall = max(server._admit_stall, int(ev.arg))
                effect = server._admit_stall
            elif ev.kind == "advance_clock":
                if self.clock is not None:
                    self.clock.advance(ev.arg)
                    effect = ev.arg
            self.applied.append((step, ev.kind, float(ev.arg), float(effect)))
        if step >= self.heal_step and not self._healed:
            self._healed = True
            if server._paged is not None:
                healed = server._paged.grow(None)
                self.applied.append((step, "heal", 0.0, float(healed)))
            server._admit_stall = 0
