"""Continuous-batching serving engine over the per-slot decode step.

The decode step (models/*.lm_decode_step) is one fused jitted program taking
per-slot positions, so every batch row advances through its own request
independently. This module adds the request-level machinery a serving
deployment needs, vLLM-style but reduced to its core:

  * slot allocation for a fixed decode batch with **mid-run admission**: a
    slot freed by a finished request is refilled from the queue on the next
    step, its cache region reset (recurrent rwkv/mamba state zeroed; KV rows
    additionally invalidated logically by the per-row validity masks in
    models/attention.py), so batch occupancy stays saturated under a request
    stream instead of draining to one straggler;
  * **paged KV** (``kv="paged"``): attention caches become a pool of
    fixed-size token blocks (serve/kv_pool.py) shared by every slot — memory
    scales with tokens actually resident, not slots x worst-case ``max_seq``,
    and a single long prompt can span blocks a dense layout could never give
    one slot. Admission is reservation-gated: a request the pool cannot
    guarantee is *deferred*, never admitted into a future OOM. The dense
    layout stays as the bit-for-bit reference (parity pinned in
    tests/test_serving_cb.py);
  * **chunked stepping** (``prefill_chunk=C``): each fused step advances
    every active slot by up to C tokens (an inner masked scan — one device
    program, C sub-steps). Prefilling slots chew C prompt tokens per step,
    so time-to-first-token drops ~C× in steps; decoding slots emit up to C
    tokens per step (the host truncates at ``max_new_tokens``), amortizing
    per-step dispatch ~C×. Mid-run admission between steps is untouched,
    and C=1 reproduces the one-token engine exactly — any C is token-exact
    against it because each sub-step IS a one-token step;
  * **token-level stepping** (``step_mode="tokens"``): instead of C uniform
    sub-steps for every slot, each fused step runs ONE variable-composition
    batch of live tokens — prefilling slots contribute ``min(C, remaining
    prompt)`` rows, decoding slots contribute one row each (vLLM-style token
    batching). Step FLOPs scale with scheduled tokens, not ``slots x C``:
    idle slots and past-prompt-end chunk rows cost nothing. Attention-only
    families (every segment kind ``attn_mlp``) only — recurrent segments
    carry per-slot state that cannot flatten, and MoE routes a decode batch
    as one capacity group where padding rows would steal expert slots; the
    server falls back to chunked stepping (recorded in
    ``meshes.fallbacks()``). Token-exact against chunked stepping because
    every scheduled row is the same one-token decode at the same position;
  * **paged-attention kernel** (``attn_impl="pallas"``, paged KV only): the
    block-table-aware Pallas kernel in ``kernels/paged_attn`` walks each
    token's mapped blocks directly instead of gathering the padded
    ``(B, nb*bs)`` K/V view; the gather path stays as the bit-exact
    reference (``attn_impl="gather"``, the default);
  * prefill-as-decode per slot with per-slot stop handling (max_new_tokens /
    max_seq), greedy or temperature sampling restricted to the true
    (unpadded) vocab;
  * one fused device program per step: next-token selection (prompt feed vs
    last sample), decode, sampling, and position advance all trace into a
    single jitted call over device arrays — tokens, per-slot positions, the
    active mask, and (paged) the block tables; the host loop only does
    request bookkeeping on the step's (sampled, emitted) output;
  * mesh-backed serving: ``BatchedServer(mesh=...)`` shards the KV/state
    caches over the ``data`` axis (slots for dense caches, *blocks* for the
    paged pool) and ``model`` axis (heads / features) via
    ``dist.meshes.SERVE_CACHE_RULES``, with the same divisibility-fallback
    bookkeeping ``Engine.sharded_path`` uses;
  * a ``serve.metrics.ServeMetrics`` rollup (occupancy %, admitted/finished/
    deferrals, tok/s, TTFT, prefill vs decode tokens, blocks-in-use %), so
    benchmarks and tests assert saturation.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import meshes
from repro.models import model_zoo
from repro.models.config import ModelConfig
from repro.models.transformer import segments_for
from repro.serve.kv_pool import PagedKV
from repro.serve.metrics import ServeMetrics

# cache leaves that stay per-slot (B at axis 1 of the layer-stacked leaf)
# even under paged KV: recurrent state is O(1) per slot, not per-token
_PER_SLOT_KEYS = frozenset({"wkv", "shift_t", "shift_c", "ssm", "conv"})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # fused steps consumed so far; one step advances a slot by up to
    # ``prefill_chunk`` tokens, so TTFT in steps is ceil(prompt_len / chunk)
    steps: int = 0
    submit_s: float | None = None  # wall clock at submission (queue entry)
    admit_s: float | None = None  # wall clock at admission into a slot
    # wall seconds from submission to first generated token — includes queue
    # wait, which is exactly what drain-then-refill's waves inflate
    ttft_s: float | None = None


def _leaf_key(path) -> str | None:
    k = path[-1] if path else None
    return getattr(k, "key", None)


def _reset_slot_rows(cache, idx, paged: bool):
    """Zero the batch rows listed in ``idx`` (padded with out-of-range
    sentinels, which the scatter drops) across the per-slot cache leaves.
    Leaves are layer-stacked (L, B, ...): rows live on axis 1; with donation
    this is an in-place row write, not a whole-cache rebuild. Under paged KV
    only the recurrent per-slot leaves are touched — block-pool leaves have
    no slot rows; recycled blocks are invalidated by the validity masks."""

    def zero(path, c):
        if paged and _leaf_key(path) not in _PER_SLOT_KEYS:
            return c
        return c.at[:, idx].set(jnp.zeros((), c.dtype))

    return jax.tree_util.tree_map_with_path(zero, cache)


class BatchedServer:
    """Fixed-slot continuous-batching server; see module docstring.

    ``admission`` picks the scheduling discipline: ``"continuous"`` (default)
    refills freed slots mid-run; ``"drain"`` is the static-batch ablation that
    only admits when every slot is empty (drain-then-refill) — the baseline
    ``benchmarks/bench_serve.py`` measures continuous batching against.

    ``kv`` picks the cache layout: ``"dense"`` (reference; every slot owns a
    ``max_seq`` row) or ``"paged"`` (block pool, ``block_size`` tokens per
    block, ``kv_blocks`` total — default dense-equivalent capacity). Models
    with no attention cache (pure recurrent) silently serve dense; the
    effective layout is ``server.kv_mode``. ``prefill_chunk`` sets the
    chunked-prefill width C (1 = classic one-token prefill).

    ``step_mode`` picks the fused-step composition: ``"chunked"`` (default,
    the reference) runs C uniform sub-steps across all slots;  ``"tokens"``
    flattens live prefill chunks and decode tokens into one variable-size
    token batch per step (attention-only families; other families fall back
    to chunked, recorded in ``meshes.fallbacks()``). The effective mode is
    ``server.step_mode``.

    ``attn_impl`` picks the paged decode-attention backend: ``"gather"``
    (default, bit-exact reference) or ``"pallas"`` (block-table kernel;
    requires ``kv="paged"``, otherwise falls back to gather with a recorded
    fallback). The effective backend is ``server.attn_impl``.
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int, max_seq: int,
                 temperature: float = 0.0, seed: int = 0, mesh=None,
                 param_specs=None, admission: str = "continuous",
                 kv: str = "dense", block_size: int = 16,
                 kv_blocks: int | None = None, prefill_chunk: int = 1,
                 step_mode: str = "chunked", attn_impl: str = "gather"):
        if cfg.family == "encdec":
            raise ValueError(
                "BatchedServer serves decoder-only families; enc-dec decode "
                "needs per-request encoder output (see examples/ seamless path)"
            )
        if admission not in ("continuous", "drain"):
            raise ValueError(f"admission must be continuous|drain, got {admission!r}")
        if kv not in ("dense", "paged"):
            raise ValueError(f"kv must be dense|paged, got {kv!r}")
        if step_mode not in ("chunked", "tokens"):
            raise ValueError(f"step_mode must be chunked|tokens, got {step_mode!r}")
        if attn_impl not in ("gather", "pallas"):
            raise ValueError(f"attn_impl must be gather|pallas, got {attn_impl!r}")
        # explicit >= 1 check, not truthiness: a falsy 0 must fail loudly
        # here instead of slipping through downstream `or` defaults
        if max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {max_seq}")
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if kv == "paged" and block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.temperature = float(temperature)
        self.admission = admission
        self.prefill_chunk = int(prefill_chunk)
        # pure-recurrent models have no per-token cache to page
        self.kv_mode = kv if not (kv == "paged" and cfg.family == "ssm") else "dense"
        if self.kv_mode == "paged":
            self._paged = PagedKV.for_model(cfg, batch_slots, max_seq,
                                            block_size, kv_blocks)
            ring = self._paged.ring
            self.cache = model_zoo.make_paged_cache(
                cfg, batch_slots, self._paged.pool.num_blocks, block_size,
                ring_num_blocks=ring.num_blocks if ring is not None else 0,
                ring_width=self._paged.ring_width,
            )
        else:
            self._paged = None
            self.cache = model_zoo.make_cache(cfg, batch_slots, max_seq)
        if attn_impl == "pallas" and self._paged is None:
            meshes.record_fallback(
                "serve_attn", "impl", 0,
                "attn_impl='pallas' needs kv='paged' (the kernel walks block "
                "tables); dense layout falls back to gather attention",
            )
            attn_impl = "gather"
        self.attn_impl = attn_impl
        if step_mode == "tokens":
            kinds = {s.kind for s in segments_for(cfg)}
            if kinds != {"attn_mlp"}:
                meshes.record_fallback(
                    "serve_step", "token_batch", 0,
                    f"token-level stepping needs attention-only segments, got "
                    f"{sorted(kinds)}: recurrent state is per-slot and MoE "
                    "capacity groups see padding rows; falling back to "
                    "chunked stepping",
                )
                step_mode = "chunked"
        self.step_mode = step_mode
        self.key = jax.random.PRNGKey(seed)
        self.active: list[Request | None] = [None] * batch_slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        # head-of-line request currently blocked by the pool: one deferral
        # *episode* per request, however many steps it stays blocked
        self._deferring_rid: int | None = None
        # wall seconds the latest step spent inside _admit (the admission
        # portion of that step's wall_s)
        self.last_admit_s = 0.0
        self.metrics = ServeMetrics(slots=batch_slots)
        if self._paged is not None:
            self.metrics.kv_blocks_total = self._paged.pool.num_blocks

        # per-slot device-program state (held as host numpy, shipped to the
        # device as tiny arrays each step; the cache stays resident on device)
        self._positions = np.zeros(batch_slots, np.int32)
        self._prompt_buf = np.zeros((batch_slots, max_seq), np.int32)
        self._prompt_len = np.zeros(batch_slots, np.int32)
        self._last_tok = np.zeros(batch_slots, np.int32)
        self._active_mask = np.zeros(batch_slots, bool)
        # the prompt buffer is the one per-slot array that is not O(slots):
        # keep its device copy resident and refresh it only on admission
        self._prompt_buf_dev = jnp.asarray(self._prompt_buf)
        # block tables ship as tiny int32 arrays, refreshed only when the
        # allocator maps or releases blocks (dense mode passes empty dummies)
        self._no_table = jnp.zeros((0,), jnp.int32)
        self._table_dev = self._ring_dev = self._no_table
        self._tables_fresh = False

        self.mesh = mesh
        self.last_sharded_path: tuple | None = None
        if mesh is not None:
            self.last_sharded_path = self.sharded_path(mesh)
            with meshes.use_mesh(mesh):
                cache_sh = meshes.tree_shardings(
                    model_zoo.cache_specs(self.cache,
                                          paged=self._paged is not None),
                    self.cache, mesh,
                    rules=(meshes.SERVE_KERNEL_CACHE_RULES
                           if self.attn_impl == "pallas"
                           else meshes.SERVE_CACHE_RULES),
                )
                self.cache = jax.device_put(self.cache, cache_sh)
                if param_specs is not None:
                    self.params = jax.device_put(
                        params, meshes.tree_shardings(param_specs, params, mesh)
                    )
                else:
                    self.params = jax.device_put(params, meshes.replicated(mesh))

        # donate the cache through both programs: the old cache is dead the
        # moment the step/reset returns, and without donation XLA keeps input
        # + output cache buffers live — a 2x peak that matters at multi-GB
        # KV-cache scale
        self._step_fn = jax.jit(self._build_step(), donate_argnums=(1,))
        self._token_step_fn = (
            jax.jit(self._build_token_step(), donate_argnums=(1,))
            if self.step_mode == "tokens" else None
        )
        self._reset_fn = jax.jit(
            functools.partial(_reset_slot_rows, paged=self._paged is not None),
            donate_argnums=(0,),
        )

    # -- sharding ------------------------------------------------------------
    def sharded_path(self, mesh) -> tuple:
        """Decide how the serving caches shard on ``mesh``: returns
        ``("gspmd", data_axes, model_axis)``. The cache batch (slot) dim — or
        the block-pool dim under paged KV — goes over the data axes when it
        divides them; head/feature dims go over the model axis when the
        family has a head-partitioned cache tensor that divides it.
        Divisibility drops are recorded in ``meshes.fallbacks()`` — the same
        bookkeeping ``Engine.sharded_path`` uses — and the dropped dim stays
        replicated (GSPMD still shards whatever per-tensor dims do resolve).
        """
        data = meshes.mesh_data_axes(mesh)
        n_data = meshes.mesh_axis_size(mesh, *data) if data else 1
        if self._paged is not None:
            nb = self._paged.pool.num_blocks
            if data and self.attn_impl == "pallas":
                meshes.record_fallback(
                    "serve_cache", "kv_blocks", 1,
                    "paged-attention kernel walks the whole block pool "
                    "through its scalar-prefetched table (any token may map "
                    "any physical block); block pool stays replicated",
                )
                data = ()
            elif data and nb % n_data != 0:
                meshes.record_fallback(
                    "serve_cache", "kv_blocks", 1,
                    f"paged pool of {nb} blocks not divisible by data axes "
                    f"{data}={n_data}; block pool stays replicated",
                )
                data = ()
        elif data and self.slots % n_data != 0:
            meshes.record_fallback(
                "serve_cache", "batch", 0,
                f"batch slots {self.slots} not divisible by data axes "
                f"{data}={n_data}; cache slots stay replicated",
            )
            data = ()
        model_axis = None
        m_size = meshes.mesh_axis_size(mesh, "model")
        if m_size > 1:
            heads = self._cache_head_dim()
            if heads is None:
                meshes.record_fallback(
                    "serve_cache", "kv_heads", 2,
                    "no head-partitioned cache tensor in this family "
                    "(latent/recurrent cache); model axis shards params only",
                )
            elif heads % m_size != 0:
                meshes.record_fallback(
                    "serve_cache", "kv_heads", 2,
                    f"cache head dim {heads} not divisible by mesh axis "
                    f"'model'={m_size}; cache heads stay replicated",
                )
            else:
                model_axis = "model"
        return "gspmd", data, model_axis

    def _cache_head_dim(self) -> int | None:
        """Size of the cache dim the model axis would partition, if any."""
        cfg = self.cfg
        if cfg.family == "ssm":  # rwkv wkv state: (B, heads, hd, hd)
            return cfg.d_model // cfg.rwkv_head_size
        if cfg.attn_kind == "mla":  # latent cache has no head dim
            return None
        return cfg.n_kv_heads

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1, "
                f"got {req.max_new_tokens}"
            )
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt len {len(req.prompt)} >= "
                f"max_seq {self.max_seq}"
            )
        if self._paged is not None:
            full, _ = self._paged.required(
                len(req.prompt), req.max_new_tokens, self.prefill_chunk,
                token_step=self.step_mode == "tokens",
            )
            if full > self._paged.pool.num_blocks:
                # deferral only makes sense when finish-time releases can
                # ever satisfy it; an impossible request must fail loudly
                raise ValueError(
                    f"request {req.rid}: needs {full} KV blocks but the pool "
                    f"only has {self._paged.pool.num_blocks}"
                )
        req.submit_s = time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        if not self.queue:
            return
        if self.admission == "drain" and any(r is not None for r in self.active):
            return  # static batching: refill only once the batch has drained
        newly = []
        now = time.perf_counter()
        token_step = self.step_mode == "tokens"
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                head = self.queue[0]
                if self._paged is not None and not self._paged.can_admit(
                    len(head.prompt), head.max_new_tokens, self.prefill_chunk,
                    token_step=token_step,
                ):
                    # the pool cannot guarantee this request's worst-case
                    # block demand: defer (FIFO head-of-line — skipping ahead
                    # would starve long prompts) until finish-time releases
                    # free capacity. Never admit into a future OOM. One
                    # deferral *episode* per request (a request blocked for
                    # ten steps is one deferred request, not ten);
                    # deferral_steps counts every blocked step.
                    if self._deferring_rid != head.rid:
                        self._deferring_rid = head.rid
                        self.metrics.deferrals += 1
                    self.metrics.deferral_steps += 1
                    break
                req = self.queue.pop(0)
                if req.rid == self._deferring_rid:
                    self._deferring_rid = None  # episode over: admitted
                if self._paged is not None:
                    self._paged.admit(slot, len(req.prompt),
                                      req.max_new_tokens, self.prefill_chunk,
                                      token_step=token_step)
                self.active[slot] = req
                req.steps = 0
                req.admit_s = now
                self._positions[slot] = 0
                self._prompt_buf[slot] = 0
                self._prompt_buf[slot, : len(req.prompt)] = req.prompt
                self._prompt_len[slot] = len(req.prompt)
                self._last_tok[slot] = 0
                self._active_mask[slot] = True
                self.metrics.admitted += 1
                newly.append(slot)
        if newly:
            # reset the freed slots' per-slot cache rows: recurrent state
            # (wkv/ssm/conv/shift) must start from zeros; dense KV rows get
            # zeroed too, belt-and-braces on top of the per-row validity
            # masks (paged block pools skip this — recycled blocks are
            # invalidated by the masks alone). Fixed (slots,) index vector
            # padded with an out-of-range sentinel (scatter drops OOB rows)
            # keeps this a single compiled program that only writes the
            # admitted rows — continuous batching calls it per admission, so
            # it must not touch the whole cache
            idx = np.full(self.slots, self.slots, np.int32)
            idx[: len(newly)] = newly
            self.cache = self._reset_fn(self.cache, jnp.asarray(idx))
            self._prompt_buf_dev = jnp.asarray(self._prompt_buf)

    # -- the fused device step -------------------------------------------------
    def _build_step(self):
        cfg = self.cfg
        decode = model_zoo.decode_fn(cfg)
        temperature = self.temperature
        vocab = cfg.vocab_size
        chunk = self.prefill_chunk
        paged = self._paged
        attn_impl = self.attn_impl
        if paged is not None:
            block_size, ring_width = paged.block_size, paged.ring_width
            max_seq = self.max_seq

        # chunk == 1: every active row runs the (single) sub-step, so the
        # PR-4 semantics hold as-is — inactive rows' dummy writes land at
        # their parked position behind the validity masks and are reset on
        # admission — and skipping the select keeps the donated cache an
        # in-place update. chunk > 1 needs it: an idle row's recurrent
        # state must freeze mid-chunk and a horizon-capped row must not
        # clobber its last KV row, at the cost of a per-sub-step select
        # (the write-gated dense scatter that would remove it is ROADMAP'd).
        gate_idle_rows = chunk > 1

        def select_rows(run, new, old):
            """Keep ``old`` for rows that did not run this sub-step. Cache
            leaves carry the slot dim at axis 1 ((L, B, ...)); paged block
            leaves have no slot rows — their writes were already gated by
            the write-ok sentinel inside the attention scatter."""

            def one(path, n, o):
                if paged is not None and _leaf_key(path) not in _PER_SLOT_KEYS:
                    return n
                m = run.reshape((1, run.shape[0]) + (1,) * (n.ndim - 2))
                return jnp.where(m, n, o)

            return jax.tree_util.tree_map_with_path(one, new, old)

        seq_limit = self.max_seq

        def step(params, cache, positions, prompt_buf, prompt_len, last_tok,
                 active, key, table, ring_table):
            b = positions.shape[0]
            rows = jnp.arange(b)

            # chunked stepping: C masked sub-steps inside the ONE jitted
            # program, each one a full one-token decode for every running
            # slot (prefill feeds the prompt buffer, decode feeds the last
            # sample — every sub-step does useful work for every row). Rows
            # at the max_seq horizon idle with cache/state/position frozen,
            # so C=1 reproduces the one-token engine bit for bit and any C
            # is token-exact against it.
            def substep(carry, _):
                cache, positions, last_tok, key = carry
                run = active & (positions < seq_limit)
                in_prompt = positions < prompt_len
                idx = jnp.clip(positions, 0, prompt_buf.shape[1] - 1)
                tok = jnp.where(in_prompt, prompt_buf[rows, idx], last_tok)
                tok = jnp.where(run, tok, 0).astype(jnp.int32)
                if paged is not None:
                    ctx = {
                        "table": table, "ring_table": ring_table,
                        "write_ok": run, "block_size": block_size,
                        "ring_width": ring_width, "max_seq": max_seq,
                        "impl": attn_impl,
                    }
                    logits, new_cache = decode(params, tok, cache, positions,
                                               paged=ctx)
                else:
                    logits, new_cache = decode(params, tok, cache, positions)
                cache = (select_rows(run, new_cache, cache)
                         if gate_idle_rows else new_cache)
                logits = logits[:, :vocab].astype(jnp.float32)
                if temperature > 0:
                    key, sub = jax.random.split(key)
                    nxt = jax.random.categorical(sub, logits / temperature,
                                                 axis=-1)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                nxt = nxt.astype(jnp.int32)
                # the sample is a real generation once the prompt is consumed
                emit = run & (positions + 1 >= prompt_len)
                positions = jnp.where(run, positions + 1, positions)
                last_tok = jnp.where(run, nxt, last_tok)
                return (cache, positions, last_tok, key), (nxt, emit)

            init = (cache, positions, last_tok, key)
            (cache, positions, last_tok, key), (toks, emits) = jax.lax.scan(
                substep, init, None, length=chunk
            )
            # toks/emits: (C, B) — the host truncates at max_new_tokens
            return cache, positions, last_tok, key, toks, emits

        return step

    def _build_token_step(self):
        """Fused decode over a flattened (T,) token batch. ``tokens``/
        ``slot``/``pos``/``live`` come from the host scheduler
        (``_step_tokens``): ``slot`` maps each row onto its cache slot,
        ``live`` gates padding rows out of cache writes. Returns per-row
        next-token samples; the host reads each slot's last scheduled row.
        Per-slot recurrent gating (``select_rows``) is unnecessary here:
        eligible families are attention-only, and every cache mutation is a
        scatter already gated by ``write_ok``."""
        cfg = self.cfg
        decode = model_zoo.decode_fn(cfg)
        temperature = self.temperature
        vocab = cfg.vocab_size
        paged = self._paged
        attn_impl = self.attn_impl
        if paged is not None:
            block_size, ring_width = paged.block_size, paged.ring_width
            max_seq = self.max_seq

        def step(params, cache, tokens, slot, pos, live, key, table,
                 ring_table):
            tok = jnp.where(live, tokens, 0).astype(jnp.int32)
            if paged is not None:
                ctx = {
                    # per-token tables: row i is token i's slot's table
                    "table": table, "ring_table": ring_table,
                    "write_ok": live, "block_size": block_size,
                    "ring_width": ring_width, "max_seq": max_seq,
                    "impl": attn_impl,
                }
                logits, cache = decode(params, tok, cache, pos, paged=ctx,
                                       slot=slot, write_ok=live)
            else:
                logits, cache = decode(params, tok, cache, pos,
                                       slot=slot, write_ok=live)
            logits = logits[:, :vocab].astype(jnp.float32)
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature,
                                             axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            return cache, nxt.astype(jnp.int32), key

        return step

    # -- stepping ---------------------------------------------------------------
    def step(self):
        """Admit into free slots, then one fused decode step. Wall time
        (``metrics.wall_s``) covers the whole step, admission included;
        ``last_admit_s`` records the admission portion so the split stays
        assertable."""
        t0 = time.perf_counter()
        self._admit()
        self.last_admit_s = time.perf_counter() - t0
        if self.step_mode == "tokens":
            self._step_tokens(t0)
        else:
            self._step_chunked(t0)

    def _step_chunked(self, t0: float):
        """C uniform masked sub-steps across all slots (the reference)."""
        # block allocation counts into wall time too: the paged-only host
        # work (ensure_step + table upload) must count against paged wall
        # time, or the CI-gated paged-vs-dense tok/s ratio flatters paged
        if self._paged is not None:
            # alloc-on-write: map blocks for the rows each slot writes this
            # step (guaranteed to succeed — admission reserved the worst case)
            changed = False
            for i, req in enumerate(self.active):
                if req is None:
                    continue
                pos = int(self._positions[i])
                n = min(self.prefill_chunk, self.max_seq - pos)
                if n > 0:
                    changed |= self._paged.ensure_step(i, pos, n)
            if changed or not self._tables_fresh:
                tf, tr = self._paged.tables()
                self._table_dev = jnp.asarray(tf)
                self._ring_dev = (jnp.asarray(tr) if tr is not None
                                  else self._no_table)
                self._tables_fresh = True
            self.metrics.kv_blocks_peak = max(
                self.metrics.kv_blocks_peak, self._paged.pool.blocks_in_use
            )
        old_pos = self._positions.copy()
        ctx = (meshes.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            out = self._step_fn(
                self.params, self.cache,
                jnp.asarray(self._positions), self._prompt_buf_dev,
                jnp.asarray(self._prompt_len), jnp.asarray(self._last_tok),
                jnp.asarray(self._active_mask), self.key,
                self._table_dev, self._ring_dev,
            )
        self.cache, positions, last_tok, self.key, toks, emits = out
        toks = np.asarray(toks)  # (C, B)
        emits = np.asarray(emits)  # sync point: one per step
        # np.array (not asarray): device arrays view as read-only numpy, and
        # _admit writes these in place on admission
        self._positions = np.array(positions)
        self._last_tok = np.array(last_tok)
        now = time.perf_counter()

        n_active = 0
        generated = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            n_active += 1
            req.steps += 1
            plen = int(self._prompt_len[i])
            # prefill vs decode token split: prompt tokens fed this step
            # (chunked stepping feeds up to C), generations counted on emit
            self.metrics.prompt_tokens += (
                min(int(self._positions[i]), plen) - min(int(old_pos[i]), plen)
            )
            for j in range(toks.shape[0]):
                # truncate at max_new: the device may over-generate up to
                # C-1 tokens in the final chunk of a request
                if not emits[j, i] or len(req.out) >= req.max_new_tokens:
                    continue
                req.out.append(int(toks[j, i]))
                generated += 1
                if req.ttft_s is None:
                    req.ttft_s = now - req.submit_s
                    self.metrics.ttft_s.append(req.ttft_s)
                    self.metrics.ttft_steps.append(req.steps)
            if (len(req.out) >= req.max_new_tokens
                    or int(self._positions[i]) >= self.max_seq):
                req.done = True
                self.finished.append(req)
                self.active[i] = None
                self._active_mask[i] = False
                self.metrics.finished += 1
                if self._paged is not None:
                    self._paged.release(i)  # free-on-finish
                    self._tables_fresh = False
        self.metrics.steps += 1
        self.metrics.active_slot_steps += n_active
        self.metrics.tokens_generated += generated
        # chunked honesty: the fused program computes every slot row for all
        # C sub-steps, live or not
        self.metrics.batched_tokens += self.slots * self.prefill_chunk
        self.metrics.wall_s += now - t0

    def _step_tokens(self, t0: float):
        """One variable-composition token batch (vLLM-style): prefilling
        slots schedule ``min(C, remaining prompt)`` rows, decoding slots one
        row each, flattened into a single fused decode whose FLOPs scale
        with live tokens. Token-exact against chunked stepping — every
        scheduled row is the same one-token decode at the same position —
        with two differences that cannot change tokens: prompt-overshoot
        rows are never scheduled, and idle slots contribute no rows."""
        chunk = self.prefill_chunk
        sched: list[tuple[int, int, int]] = []  # (slot, start_pos, n_rows)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            p = int(self._positions[i])
            plen = int(self._prompt_len[i])
            n = min(chunk, plen - p) if p < plen else 1
            n = min(n, self.max_seq - p)
            sched.append((i, p, n))
        t_live = sum(n for _, _, n in sched)
        if t_live == 0:
            # nothing runnable this step (empty batch); still a step
            self.metrics.steps += 1
            self.metrics.wall_s += time.perf_counter() - t0
            return
        # pad the batch to an 8-token bucket: bounds the set of distinct
        # shapes the jitted step compiles for; padding rows are dead (live
        # False gates their writes, their samples are never read)
        t_pad = max(8, -(-t_live // 8) * 8)
        tokens = np.zeros(t_pad, np.int32)
        slot_ids = np.zeros(t_pad, np.int32)
        pos = np.zeros(t_pad, np.int32)
        live = np.zeros(t_pad, bool)
        last_row: dict[int, int] = {}
        k = 0
        for i, p, n in sched:
            plen = int(self._prompt_len[i])
            if p < plen:
                tokens[k:k + n] = self._prompt_buf[i, p:p + n]
            else:
                tokens[k] = self._last_tok[i]
            slot_ids[k:k + n] = i
            pos[k:k + n] = np.arange(p, p + n, dtype=np.int32)
            live[k:k + n] = True
            last_row[i] = k + n - 1
            k += n
        if self._paged is not None:
            for i, p, n in sched:
                self._paged.ensure_step(i, p, n)
            tf, tr = self._paged.token_tables(slot_ids)
            table_dev = jnp.asarray(tf)
            ring_dev = (jnp.asarray(tr) if tr is not None
                        else self._no_table)
            self.metrics.kv_blocks_peak = max(
                self.metrics.kv_blocks_peak, self._paged.pool.blocks_in_use
            )
        else:
            table_dev = ring_dev = self._no_table
        ctx = (meshes.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            self.cache, nxt, self.key = self._token_step_fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(slot_ids), jnp.asarray(pos), jnp.asarray(live),
                self.key, table_dev, ring_dev,
            )
        nxt = np.asarray(nxt)  # sync point: one per step
        now = time.perf_counter()

        n_active = 0
        generated = 0
        for i, p, n in sched:
            req = self.active[i]
            n_active += 1
            req.steps += 1
            plen = int(self._prompt_len[i])
            new_p = p + n
            self._positions[i] = new_p
            self.metrics.prompt_tokens += min(new_p, plen) - min(p, plen)
            if new_p >= plen:
                # the slot's last scheduled row sits at the final prompt
                # position or beyond: its sample is a real generation
                tok = int(nxt[last_row[i]])
                self._last_tok[i] = tok
                if len(req.out) < req.max_new_tokens:
                    req.out.append(tok)
                    generated += 1
                    if req.ttft_s is None:
                        req.ttft_s = now - req.submit_s
                        self.metrics.ttft_s.append(req.ttft_s)
                        self.metrics.ttft_steps.append(req.steps)
            if (len(req.out) >= req.max_new_tokens
                    or new_p >= self.max_seq):
                req.done = True
                self.finished.append(req)
                self.active[i] = None
                self._active_mask[i] = False
                self.metrics.finished += 1
                if self._paged is not None:
                    self._paged.release(i)  # free-on-finish
                    self._tables_fresh = False
        self.metrics.steps += 1
        self.metrics.active_slot_steps += n_active
        self.metrics.tokens_generated += generated
        self.metrics.batched_tokens += t_live
        self.metrics.wall_s += now - t0

    def reset_metrics(self):
        kv_total = self.metrics.kv_blocks_total
        self.metrics = ServeMetrics(slots=self.slots, kv_blocks_total=kv_total)

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Step until queue and slots drain (or ``max_steps``); returns ALL
        finished requests so far, in deterministic ``rid`` order."""
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) and (
            max_steps is None or steps < max_steps
        ):
            self.step()
            steps += 1
        return sorted(self.finished, key=lambda r: r.rid)


def generate_greedy(cfg: ModelConfig, params, prompts: list[list[int]],
                    max_new_tokens: int, max_seq: int | None = None):
    """Convenience: run a batch of prompts to completion, return token lists
    (rid order == prompt order, straight from ``run``)."""
    # `is None`, not `or`: max_seq=0 must reach BatchedServer's >= 1 check
    # as the caller's value, not silently become a derived default
    if max_seq is None:
        max_seq = max(len(p) for p in prompts) + max_new_tokens + 1
    server = BatchedServer(cfg, params, batch_slots=len(prompts), max_seq=max_seq)
    for i, p in enumerate(prompts):
        server.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new_tokens))
    return [r.out for r in server.run()]
