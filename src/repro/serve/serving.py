"""Batched serving: continuous-batching request manager over the decode step.

The decode step itself (models/*.lm_decode_step) is one fused jitted program
with sharded KV caches (flash-decode pattern, see models/attention.py). This
module adds the request-level machinery a serving deployment needs: slot
allocation for a fixed decode batch, prefill-then-decode admission, greedy /
temperature sampling restricted to the true (unpadded) vocab, and per-request
stop handling — a vLLM-style scheduler reduced to its core.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_zoo
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedServer:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int, max_seq: int,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.temperature = temperature
        self.cache = model_zoo.make_cache(cfg, batch_slots, max_seq)
        self._decode = jax.jit(model_zoo.decode_fn(cfg))
        self.active: list[Request | None] = [None] * batch_slots
        self.pos = 0
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                self.active[slot] = self.queue.pop(0)

    # -- stepping ---------------------------------------------------------------
    def _sample(self, logits):
        logits = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits / self.temperature, axis=-1)

    def step(self):
        """One synchronous decode step across all slots."""
        self._admit()
        tokens = np.zeros(self.slots, np.int32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            # feed prompt tokens first (prefill-as-decode), then generations
            consumed = self.pos_of(req)
            tokens[i] = (
                req.prompt[consumed]
                if consumed < len(req.prompt)
                else req.out[-1]
            )
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache, jnp.int32(self.pos)
        )
        nxt = np.asarray(self._sample(logits))
        for i, req in enumerate(self.active):
            if req is None:
                continue
            consumed = self.pos_of(req)
            if consumed + 1 >= len(req.prompt):
                req.out.append(int(nxt[i]))
            req._steps = getattr(req, "_steps", 0) + 1
            if len(req.out) >= req.max_new_tokens or self.pos + 1 >= self.max_seq:
                req.done = True
                self.finished.append(req)
                self.active[i] = None
        self.pos += 1

    @staticmethod
    def pos_of(req: Request) -> int:
        return getattr(req, "_steps", 0)

    def run(self, max_steps: int | None = None):
        steps = 0
        while (self.queue or any(self.active)) and (
            max_steps is None or steps < max_steps
        ):
            self.step()
            steps += 1
        return self.finished


def generate_greedy(cfg: ModelConfig, params, prompts: list[list[int]],
                    max_new_tokens: int, max_seq: int | None = None):
    """Convenience: run a batch of prompts to completion, return token lists."""
    max_seq = max_seq or (max(len(p) for p in prompts) + max_new_tokens + 1)
    server = BatchedServer(cfg, params, batch_slots=len(prompts), max_seq=max_seq)
    for i, p in enumerate(prompts):
        server.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new_tokens))
    done = server.run()
    return [r.out for r in sorted(done, key=lambda r: r.rid)]
