"""Continuous-batching serving engine over the per-slot decode step.

The decode step (models/*.lm_decode_step) is one fused jitted program taking
per-slot positions, so every batch row advances through its own request
independently. This module adds the request-level machinery a serving
deployment needs, vLLM-style but reduced to its core:

  * slot allocation for a fixed decode batch with **mid-run admission**: a
    slot freed by a finished request is refilled from the queue on the next
    step, its cache region reset (recurrent rwkv/mamba state zeroed; KV rows
    additionally invalidated logically by the per-row validity masks in
    models/attention.py), so batch occupancy stays saturated under a request
    stream instead of draining to one straggler;
  * prefill-as-decode per slot with per-slot stop handling (max_new_tokens /
    max_seq), greedy or temperature sampling restricted to the true
    (unpadded) vocab;
  * one fused device program per step: next-token selection (prompt feed vs
    last sample), decode, sampling, and position advance all trace into a
    single jitted call over device arrays — tokens, per-slot positions, and
    the active mask; the host loop only does request bookkeeping on the
    step's (sampled, emitted) output;
  * mesh-backed serving: ``BatchedServer(mesh=...)`` shards the KV/state
    caches over the ``data`` axis (slots) and ``model`` axis (heads /
    features) via ``dist.meshes.SERVE_CACHE_RULES``, with the same
    divisibility-fallback bookkeeping ``Engine.sharded_path`` uses;
  * a ``serve.metrics.ServeMetrics`` rollup (occupancy %, admitted/finished,
    tok/s, time-to-first-token) so benchmarks and tests assert saturation.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import meshes
from repro.models import model_zoo
from repro.models.config import ModelConfig
from repro.serve.metrics import ServeMetrics


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # decode steps consumed so far == the slot's current position; one prompt
    # token or one generation per step (prefill-as-decode)
    steps: int = 0
    submit_s: float | None = None  # wall clock at submission (queue entry)
    admit_s: float | None = None  # wall clock at admission into a slot
    # wall seconds from submission to first generated token — includes queue
    # wait, which is exactly what drain-then-refill's waves inflate
    ttft_s: float | None = None


class BatchedServer:
    """Fixed-slot continuous-batching server; see module docstring.

    ``admission`` picks the scheduling discipline: ``"continuous"`` (default)
    refills freed slots mid-run; ``"drain"`` is the static-batch ablation that
    only admits when every slot is empty (drain-then-refill) — the baseline
    ``benchmarks/bench_serve.py`` measures continuous batching against.
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int, max_seq: int,
                 temperature: float = 0.0, seed: int = 0, mesh=None,
                 param_specs=None, admission: str = "continuous"):
        if cfg.family == "encdec":
            raise ValueError(
                "BatchedServer serves decoder-only families; enc-dec decode "
                "needs per-request encoder output (see examples/ seamless path)"
            )
        if admission not in ("continuous", "drain"):
            raise ValueError(f"admission must be continuous|drain, got {admission!r}")
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.temperature = float(temperature)
        self.admission = admission
        self.cache = model_zoo.make_cache(cfg, batch_slots, max_seq)
        self.key = jax.random.PRNGKey(seed)
        self.active: list[Request | None] = [None] * batch_slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.metrics = ServeMetrics(slots=batch_slots)

        # per-slot device-program state (held as host numpy, shipped to the
        # device as tiny arrays each step; the cache stays resident on device)
        self._positions = np.zeros(batch_slots, np.int32)
        self._prompt_buf = np.zeros((batch_slots, max_seq), np.int32)
        self._prompt_len = np.zeros(batch_slots, np.int32)
        self._last_tok = np.zeros(batch_slots, np.int32)
        self._active_mask = np.zeros(batch_slots, bool)
        # the prompt buffer is the one per-slot array that is not O(slots):
        # keep its device copy resident and refresh it only on admission
        self._prompt_buf_dev = jnp.asarray(self._prompt_buf)

        self.mesh = mesh
        self.last_sharded_path: tuple | None = None
        if mesh is not None:
            self.last_sharded_path = self.sharded_path(mesh)
            with meshes.use_mesh(mesh):
                cache_sh = meshes.tree_shardings(
                    model_zoo.cache_specs(self.cache), self.cache, mesh,
                    rules=meshes.SERVE_CACHE_RULES,
                )
                self.cache = jax.device_put(self.cache, cache_sh)
                if param_specs is not None:
                    self.params = jax.device_put(
                        params, meshes.tree_shardings(param_specs, params, mesh)
                    )
                else:
                    self.params = jax.device_put(params, meshes.replicated(mesh))

        # donate the cache through both programs: the old cache is dead the
        # moment the step/reset returns, and without donation XLA keeps input
        # + output cache buffers live — a 2x peak that matters at multi-GB
        # KV-cache scale
        self._step_fn = jax.jit(self._build_step(), donate_argnums=(1,))
        self._reset_fn = jax.jit(self._reset_slots, donate_argnums=(0,))

    # -- sharding ------------------------------------------------------------
    def sharded_path(self, mesh) -> tuple:
        """Decide how the serving caches shard on ``mesh``: returns
        ``("gspmd", data_axes, model_axis)``. The cache batch (slot) dim goes
        over the data axes when the slot count divides them; head/feature
        dims go over the model axis when the family has a head-partitioned
        cache tensor that divides it. Divisibility drops are recorded in
        ``meshes.fallbacks()`` — the same bookkeeping ``Engine.sharded_path``
        uses — and the dropped dim stays replicated (GSPMD still shards
        whatever per-tensor dims do resolve)."""
        data = meshes.mesh_data_axes(mesh)
        n_data = meshes.mesh_axis_size(mesh, *data) if data else 1
        if data and self.slots % n_data != 0:
            meshes.record_fallback(
                "serve_cache", "batch", 0,
                f"batch slots {self.slots} not divisible by data axes "
                f"{data}={n_data}; cache slots stay replicated",
            )
            data = ()
        model_axis = None
        m_size = meshes.mesh_axis_size(mesh, "model")
        if m_size > 1:
            heads = self._cache_head_dim()
            if heads is None:
                meshes.record_fallback(
                    "serve_cache", "kv_heads", 2,
                    "no head-partitioned cache tensor in this family "
                    "(latent/recurrent cache); model axis shards params only",
                )
            elif heads % m_size != 0:
                meshes.record_fallback(
                    "serve_cache", "kv_heads", 2,
                    f"cache head dim {heads} not divisible by mesh axis "
                    f"'model'={m_size}; cache heads stay replicated",
                )
            else:
                model_axis = "model"
        return "gspmd", data, model_axis

    def _cache_head_dim(self) -> int | None:
        """Size of the cache dim the model axis would partition, if any."""
        cfg = self.cfg
        if cfg.family == "ssm":  # rwkv wkv state: (B, heads, hd, hd)
            return cfg.d_model // cfg.rwkv_head_size
        if cfg.attn_kind == "mla":  # latent cache has no head dim
            return None
        return cfg.n_kv_heads

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) >= self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt len {len(req.prompt)} >= "
                f"max_seq {self.max_seq}"
            )
        req.submit_s = time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        if not self.queue:
            return
        if self.admission == "drain" and any(r is not None for r in self.active):
            return  # static batching: refill only once the batch has drained
        newly = []
        now = time.perf_counter()
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                req.steps = 0
                req.admit_s = now
                self._positions[slot] = 0
                self._prompt_buf[slot] = 0
                self._prompt_buf[slot, : len(req.prompt)] = req.prompt
                self._prompt_len[slot] = len(req.prompt)
                self._last_tok[slot] = 0
                self._active_mask[slot] = True
                self.metrics.admitted += 1
                newly.append(slot)
        if newly:
            # reset the freed slots' cache rows: recurrent state (wkv/ssm/
            # conv/shift) must start from zeros; KV rows get zeroed too,
            # belt-and-braces on top of the per-row validity masks. Fixed
            # (slots,) index vector padded with an out-of-range sentinel
            # (scatter drops OOB rows) keeps this a single compiled program
            # that only writes the admitted rows — continuous batching calls
            # it per admission, so it must not touch the whole cache
            idx = np.full(self.slots, self.slots, np.int32)
            idx[: len(newly)] = newly
            self.cache = self._reset_fn(self.cache, jnp.asarray(idx))
            self._prompt_buf_dev = jnp.asarray(self._prompt_buf)

    @staticmethod
    def _reset_slots(cache, idx):
        """Zero the batch rows listed in ``idx`` (padded with out-of-range
        sentinels, which the scatter drops) across every cache leaf. Leaves
        are layer-stacked (L, B, ...): rows live on axis 1; with donation
        this is an in-place row write, not a whole-cache rebuild."""

        def zero(c):
            return c.at[:, idx].set(jnp.zeros((), c.dtype))

        return jax.tree_util.tree_map(zero, cache)

    # -- the fused device step -------------------------------------------------
    def _build_step(self):
        cfg = self.cfg
        decode = model_zoo.decode_fn(cfg)
        temperature = self.temperature
        vocab = cfg.vocab_size

        def step(params, cache, positions, prompt_buf, prompt_len, last_tok,
                 active, key):
            b = positions.shape[0]
            rows = jnp.arange(b)
            # next input per slot: prompt token while prefilling, else the
            # last sampled token; inactive slots feed a dummy 0 at their
            # parked position (their writes are reset on admission)
            in_prompt = positions < prompt_len
            idx = jnp.clip(positions, 0, prompt_buf.shape[1] - 1)
            tok = jnp.where(in_prompt, prompt_buf[rows, idx], last_tok)
            tok = jnp.where(active, tok, 0).astype(jnp.int32)
            logits, cache = decode(params, tok, cache, positions)
            logits = logits[:, :vocab].astype(jnp.float32)
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32)
            # the sample is a real generation once the prompt is consumed
            emitted = active & (positions + 1 >= prompt_len)
            positions = jnp.where(active, positions + 1, positions)
            last_tok = jnp.where(active, nxt, last_tok)
            return cache, positions, last_tok, key, nxt, emitted

        return step

    # -- stepping ---------------------------------------------------------------
    def step(self):
        """Admit into free slots, then one fused decode step across all slots."""
        self._admit()
        t0 = time.perf_counter()
        ctx = (meshes.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            out = self._step_fn(
                self.params, self.cache,
                jnp.asarray(self._positions), self._prompt_buf_dev,
                jnp.asarray(self._prompt_len), jnp.asarray(self._last_tok),
                jnp.asarray(self._active_mask), self.key,
            )
        self.cache, positions, last_tok, self.key, nxt, emitted = out
        nxt = np.asarray(nxt)
        emitted = np.asarray(emitted)  # sync point: one per step
        # np.array (not asarray): device arrays view as read-only numpy, and
        # _admit writes these in place on admission
        self._positions = np.array(positions)
        self._last_tok = np.array(last_tok)
        now = time.perf_counter()

        n_active = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            n_active += 1
            req.steps += 1
            if emitted[i]:
                req.out.append(int(nxt[i]))
                if req.ttft_s is None:
                    req.ttft_s = now - req.submit_s
                    self.metrics.ttft_s.append(req.ttft_s)
                    self.metrics.ttft_steps.append(req.steps)
            else:
                self.metrics.prompt_tokens += 1
            if len(req.out) >= req.max_new_tokens or req.steps >= self.max_seq:
                req.done = True
                self.finished.append(req)
                self.active[i] = None
                self._active_mask[i] = False
                self.metrics.finished += 1
        self.metrics.steps += 1
        self.metrics.active_slot_steps += n_active
        self.metrics.tokens_generated += int(emitted.sum())
        self.metrics.wall_s += now - t0

    def reset_metrics(self):
        self.metrics = ServeMetrics(slots=self.slots)

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Step until queue and slots drain (or ``max_steps``); returns ALL
        finished requests so far, in deterministic ``rid`` order."""
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) and (
            max_steps is None or steps < max_steps
        ):
            self.step()
            steps += 1
        return sorted(self.finished, key=lambda r: r.rid)


def generate_greedy(cfg: ModelConfig, params, prompts: list[list[int]],
                    max_new_tokens: int, max_seq: int | None = None):
    """Convenience: run a batch of prompts to completion, return token lists
    (rid order == prompt order, straight from ``run``)."""
    max_seq = max_seq or (max(len(p) for p in prompts) + max_new_tokens + 1)
    server = BatchedServer(cfg, params, batch_slots=len(prompts), max_seq=max_seq)
    for i, p in enumerate(prompts):
        server.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new_tokens))
    return [r.out for r in server.run()]
