"""Continuous-batching serving engine over the per-slot decode step.

The decode step (models/*.lm_decode_step) is one fused jitted program taking
per-slot positions, so every batch row advances through its own request
independently. This module adds the request-level machinery a serving
deployment needs, vLLM-style but reduced to its core:

  * slot allocation for a fixed decode batch with **mid-run admission**: a
    slot freed by a finished request is refilled from the queue on the next
    step, its cache region reset (recurrent rwkv/mamba state zeroed; KV rows
    additionally invalidated logically by the per-row validity masks in
    models/attention.py), so batch occupancy stays saturated under a request
    stream instead of draining to one straggler;
  * **paged KV** (``kv="paged"``): attention caches become a pool of
    fixed-size token blocks (serve/kv_pool.py) shared by every slot — memory
    scales with tokens actually resident, not slots x worst-case ``max_seq``,
    and a single long prompt can span blocks a dense layout could never give
    one slot. Admission is reservation-gated: a request the pool cannot
    guarantee is *deferred*, never admitted into a future OOM. The dense
    layout stays as the bit-for-bit reference (parity pinned in
    tests/test_serving_cb.py);
  * **chunked stepping** (``prefill_chunk=C``): each fused step advances
    every active slot by up to C tokens (an inner masked scan — one device
    program, C sub-steps). Prefilling slots chew C prompt tokens per step,
    so time-to-first-token drops ~C× in steps; decoding slots emit up to C
    tokens per step (the host truncates at ``max_new_tokens``), amortizing
    per-step dispatch ~C×. Mid-run admission between steps is untouched,
    and C=1 reproduces the one-token engine exactly — any C is token-exact
    against it because each sub-step IS a one-token step;
  * **token-level stepping** (``step_mode="tokens"``): instead of C uniform
    sub-steps for every slot, each fused step runs ONE variable-composition
    batch of live tokens — prefilling slots contribute ``min(C, remaining
    prompt)`` rows, decoding slots contribute one row each (vLLM-style token
    batching). Step FLOPs scale with scheduled tokens, not ``slots x C``:
    idle slots and past-prompt-end chunk rows cost nothing. Attention-only
    families (every segment kind ``attn_mlp``) only — recurrent segments
    carry per-slot state that cannot flatten, and MoE routes a decode batch
    as one capacity group where padding rows would steal expert slots; the
    server falls back to chunked stepping (recorded in
    ``meshes.fallbacks()``). Token-exact against chunked stepping because
    every scheduled row is the same one-token decode at the same position;
  * **paged-attention kernel** (``attn_impl="pallas"``, paged KV only): the
    block-table-aware Pallas kernel in ``kernels/paged_attn`` walks each
    token's mapped blocks directly instead of gathering the padded
    ``(B, nb*bs)`` K/V view; the gather path stays as the bit-exact
    reference (``attn_impl="gather"``, the default);
  * prefill-as-decode per slot with per-slot stop handling (max_new_tokens /
    max_seq), greedy or temperature sampling restricted to the true
    (unpadded) vocab;
  * one fused device program per step: next-token selection (prompt feed vs
    last sample), decode, sampling, and position advance all trace into a
    single jitted call over device arrays — tokens, per-slot positions, the
    active mask, and (paged) the block tables; the host loop only does
    request bookkeeping on the step's (sampled, emitted) output;
  * mesh-backed serving: ``BatchedServer(mesh=...)`` shards the KV/state
    caches over the ``data`` axis (slots for dense caches, *blocks* for the
    paged pool) and ``model`` axis (heads / features) via
    ``dist.meshes.SERVE_CACHE_RULES``, with the same divisibility-fallback
    bookkeeping ``Engine.sharded_path`` uses;
  * **preemptive scheduling** (serve/scheduler.py): admission is a priority
    queue (lower ``Request.priority`` = more important, FIFO within a
    class) with per-request deadlines (TTFT and end-to-end, measured on the
    server clock from submission). When a higher-priority request is
    blocked — no free slot, or the paged pool cannot cover its reservation
    — the scheduler evicts a victim (lowest priority class, most recently
    admitted): the victim's blocks are ``release()``d and it is requeued
    **carrying its generated tokens**, resuming later by chunked prefill
    over ``prompt + generated``. Under greedy decoding the resume is
    token-exact vs an uncontended run: the re-prefill recomputes exactly
    the KV prefix the evicted cache held, and emission restarts at the end
    of the carried tokens (``tests/test_serve_scheduler.py`` pins this
    across GQA/MLA x dense/paged x chunked/tokens). Deadline misses are
    *cancelled* — blocks freed immediately, status
    ``CANCELLED_DEADLINE`` — so overload sheds load instead of occupying
    slots; every request ends in a terminal status (``FINISHED`` /
    ``CANCELLED_DEADLINE`` / ``REJECTED``);
  * **decode-time pool pressure never raises out of ``run()``**: mid-run
    ``ensure_step`` failures (possible when a fault plan shrinks the pool
    out from under admission's reservations) are routed through the same
    preemption machinery — victims are evicted until the write fits, the
    failing slot itself evicted last;
  * **fault injection** (serve/faults.py): a seeded ``FaultPlan`` applies
    scripted pool shrinkage, forced preemptions, admission stalls, and
    virtual-clock deadline pressure at chosen steps, driving the chaos
    suite (``tests/test_serve_chaos.py``); ``debug_checks=`` (default: on
    under pytest, off in benches) asserts the block-pool invariants after
    every step so corruption fails at the step that caused it;
  * **prefix sharing** (``prefix_cache``, paged + attention-only families):
    fully-written feed blocks register content keys in the pool's
    ``PrefixIndex``; a new request whose prompt starts with a resident
    chain maps those blocks *shared* (refcount bump, no copy, no free-list
    pop) and starts prefill at its first divergent position — the final
    prompt position is always recomputed, so emission and sampling run the
    unchanged step path. Writes into a still-shared block COW-split it
    first (``cow_step`` swaps in a private copy; the device rows are
    duplicated by a tiny jitted scatter before the fused step), so sharers
    never observe another request's scatters — token-exact vs the unshared
    pool (pinned in ``tests/test_serve_prefix.py`` across GQA/MLA x
    gather/pallas x chunked/tokens, including preempt-then-resume).
    Ineligible shapes (SWA ring pools — ring rows wrap, so a sharer would
    be missing skipped window writes — and families with per-slot
    recurrent/MoE state, whose skipped positions carry state KV blocks
    don't) fall back with a recorded fallback;
  * **multi-tenant fairness** (``scheduler="wdrr"`` + ``tenant_weights``):
    weighted deficit round robin over ``Request.tenant`` queues inside
    each priority class (serve/scheduler.py) — tenants get admission
    shares proportional to weight under saturation, with per-tenant
    rollups in ``metrics.per_tenant``;
  * a ``serve.metrics.ServeMetrics`` rollup (occupancy %, admitted/finished/
    deferrals, tok/s, TTFT, prefill vs decode tokens, blocks-in-use %,
    prefix hits/skipped prefill tokens, KV bytes written (COW splits
    included), preemptions/recompute/deadline-miss counters and
    per-priority / per-tenant rollups), so benchmarks and tests assert
    saturation and robustness.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import meshes
from repro.models import model_zoo
from repro.models.config import ModelConfig
from repro.models.transformer import segments_for
from repro.serve import scheduler as sched
from repro.serve.kv_pool import PagedKV, PoolExhausted, prefix_keys
from repro.serve.metrics import ServeMetrics

# cache leaves that stay per-slot (B at axis 1 of the layer-stacked leaf)
# even under paged KV: recurrent state is O(1) per slot, not per-token
_PER_SLOT_KEYS = frozenset({"wkv", "shift_t", "shift_c", "ssm", "conv"})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # fused steps consumed since the LAST admission; one step advances a slot
    # by up to ``prefill_chunk`` tokens, so TTFT in steps is
    # ceil(prompt_len / chunk) for a never-preempted request
    steps: int = 0
    submit_s: float | None = None  # server clock at submission (queue entry)
    admit_s: float | None = None  # server clock at FIRST admission into a slot
    # wall seconds from submission to first generated token — includes queue
    # wait, which is exactly what drain-then-refill's waves inflate
    ttft_s: float | None = None
    # scheduling: lower priority value = more important (0 = interactive
    # class); deadlines are wall budgets from submission on the server clock
    # (deadline_ttft_s until the first token, deadline_s end to end) — a miss
    # cancels the request and frees its blocks immediately
    priority: int = 1
    deadline_ttft_s: float | None = None
    deadline_s: float | None = None
    # lifecycle: QUEUED -> RUNNING -> FINISHED, with PREEMPTED (requeued,
    # will resume), CANCELLED_DEADLINE, REJECTED (see serve/scheduler.py)
    status: str = sched.QUEUED
    preemptions: int = 0  # times evicted; resume re-prefills prompt+out
    seq: int = -1  # submission order (scheduler-assigned; kept across resumes)
    admit_seq: int = -1  # admission order — drives victim selection
    submit_step: int | None = None  # server step counter at submission
    # tenant id for weighted fairness (scheduler="wdrr") and the per-tenant
    # metrics rollup; the default folds everything into one tenant
    tenant: int | str = 0
    # prompt positions the prefix cache served from resident shared blocks
    # at the LAST admission (prefill starts at this offset)
    prefix_shared_tokens: int = 0


def _leaf_key(path) -> str | None:
    k = path[-1] if path else None
    return getattr(k, "key", None)


def _cow_copy_blocks(cache, src, dst):
    """Duplicate block rows ``src -> dst`` across the block-pool cache
    leaves (copy-on-write split: the writer got a private physical block and
    the shared original must be byte-identical in it before the next step's
    scatter). Leaves are layer-stacked ``(L, num_blocks, block_size, ...)``
    — blocks live on axis 1. Padding entries carry ``dst == num_blocks``
    (out of range: jax drops OOB scatter updates, same gating the paged
    write path uses), so one compiled program serves any pad bucket."""

    def one(path, c):
        if _leaf_key(path) in _PER_SLOT_KEYS:
            return c
        return c.at[:, dst].set(c[:, src])

    return jax.tree_util.tree_map_with_path(one, cache)


def _cache_row_bytes(cache) -> int:
    """Bytes of cache one written position costs, summed over every
    non-per-slot leaf and all layers: leaves are layer-stacked ``(L, B_or_NB,
    S_or_bs, tail...)``, so one row is ``L * prod(tail)`` elements per leaf.
    Recurrent per-slot leaves are O(1) state updates, not per-token KV —
    excluded (a pure-recurrent family reports 0)."""
    total = 0
    for path, c in jax.tree_util.tree_leaves_with_path(cache):
        if _leaf_key(path) in _PER_SLOT_KEYS or c.ndim < 3:
            continue
        total += int(c.shape[0]) * int(np.prod(c.shape[3:], dtype=np.int64)) \
            * c.dtype.itemsize
    return total


def _reset_slot_rows(cache, idx, paged: bool):
    """Zero the batch rows listed in ``idx`` (padded with out-of-range
    sentinels, which the scatter drops) across the per-slot cache leaves.
    Leaves are layer-stacked (L, B, ...): rows live on axis 1; with donation
    this is an in-place row write, not a whole-cache rebuild. Under paged KV
    only the recurrent per-slot leaves are touched — block-pool leaves have
    no slot rows; recycled blocks are invalidated by the validity masks."""

    def zero(path, c):
        if paged and _leaf_key(path) not in _PER_SLOT_KEYS:
            return c
        return c.at[:, idx].set(jnp.zeros((), c.dtype))

    return jax.tree_util.tree_map_with_path(zero, cache)


class BatchedServer:
    """Fixed-slot continuous-batching server; see module docstring.

    ``admission`` picks the scheduling discipline: ``"continuous"`` (default)
    refills freed slots mid-run; ``"drain"`` is the static-batch ablation that
    only admits when every slot is empty (drain-then-refill) — the baseline
    ``benchmarks/bench_serve.py`` measures continuous batching against.

    ``kv`` picks the cache layout: ``"dense"`` (reference; every slot owns a
    ``max_seq`` row) or ``"paged"`` (block pool, ``block_size`` tokens per
    block, ``kv_blocks`` total — default dense-equivalent capacity). Models
    with no attention cache (pure recurrent) silently serve dense; the
    effective layout is ``server.kv_mode``. ``prefill_chunk`` sets the
    chunked-prefill width C (1 = classic one-token prefill).

    ``step_mode`` picks the fused-step composition: ``"chunked"`` (default,
    the reference) runs C uniform sub-steps across all slots;  ``"tokens"``
    flattens live prefill chunks and decode tokens into one variable-size
    token batch per step (attention-only families; other families fall back
    to chunked, recorded in ``meshes.fallbacks()``). The effective mode is
    ``server.step_mode``.

    ``attn_impl`` picks the paged decode-attention backend: ``"gather"``
    (default, bit-exact reference) or ``"pallas"`` (block-table kernel;
    requires ``kv="paged"``, otherwise falls back to gather with a recorded
    fallback). The effective backend is ``server.attn_impl``.

    ``scheduler`` picks the admission policy: ``"priority"`` (default —
    priority classes, deadlines, and preemption; with uniform priorities and
    no deadlines it behaves exactly like FIFO) or ``"fifo"`` (the
    pre-scheduler ablation: submission order, no preemption). ``preemption``
    overrides the policy default (priority: on, fifo: off).

    ``debug_checks`` asserts the paged-pool allocator invariants after every
    step (``KVBlockPool.check``); default None resolves to the
    ``REPRO_SERVE_DEBUG_CHECKS`` env var ("0"/"1") or, absent that, to
    "running under pytest" — on in tests/CI, off in benches.

    ``fault_plan`` installs a ``serve.faults.FaultPlan`` applied at the top
    of each step; a plan carrying a ``VirtualClock`` also becomes the server
    ``clock`` (the callable behind every timestamp and deadline — defaults
    to ``time.perf_counter``).
    """

    def __init__(self, cfg: ModelConfig, params, batch_slots: int, max_seq: int,
                 temperature: float = 0.0, seed: int = 0, mesh=None,
                 param_specs=None, admission: str = "continuous",
                 kv: str = "dense", block_size: int = 16,
                 kv_blocks: int | None = None, prefill_chunk: int = 1,
                 step_mode: str = "chunked", attn_impl: str = "gather",
                 scheduler: str = "priority", preemption: bool | None = None,
                 debug_checks: bool | None = None, fault_plan=None,
                 clock=None, prefix_cache: bool | None = None,
                 tenant_weights: dict | None = None):
        if cfg.family == "encdec":
            raise ValueError(
                "BatchedServer serves decoder-only families; enc-dec decode "
                "needs per-request encoder output (see examples/ seamless path)"
            )
        if admission not in ("continuous", "drain"):
            raise ValueError(f"admission must be continuous|drain, got {admission!r}")
        if kv not in ("dense", "paged"):
            raise ValueError(f"kv must be dense|paged, got {kv!r}")
        if step_mode not in ("chunked", "tokens"):
            raise ValueError(f"step_mode must be chunked|tokens, got {step_mode!r}")
        if attn_impl not in ("gather", "pallas"):
            raise ValueError(f"attn_impl must be gather|pallas, got {attn_impl!r}")
        if scheduler not in sched.POLICIES:
            raise ValueError(
                f"scheduler must be one of {sched.POLICIES}, got {scheduler!r}"
            )
        # explicit >= 1 check, not truthiness: a falsy 0 must fail loudly
        # here instead of slipping through downstream `or` defaults
        if max_seq < 1:
            raise ValueError(f"max_seq must be >= 1, got {max_seq}")
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if kv == "paged" and block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self.temperature = float(temperature)
        self.admission = admission
        self.prefill_chunk = int(prefill_chunk)
        # pure-recurrent models have no per-token cache to page
        self.kv_mode = kv if not (kv == "paged" and cfg.family == "ssm") else "dense"
        # prefix sharing needs (a) a paged pool without a SWA ring (ring rows
        # wrap: a sharer skipping prefill would be missing the skipped
        # positions' window writes) and (b) attention-only segments (skipped
        # positions carry recurrent/MoE-capacity state that blocks don't
        # hold). None = on wherever eligible; an explicit True on an
        # ineligible shape records a fallback instead of serving wrong KV.
        kinds = {s.kind for s in segments_for(cfg)}
        prefix_ok = (self.kv_mode == "paged" and kinds == {"attn_mlp"})
        if prefix_cache is None:
            prefix_cache = prefix_ok
        elif prefix_cache and not prefix_ok:
            meshes.record_fallback(
                "serve_prefix", "prefix_cache", 0,
                f"prefix sharing needs paged KV over attention-only segments "
                f"(kv={self.kv_mode!r}, kinds={sorted(kinds)}); serving "
                "unshared",
            )
            prefix_cache = False
        self.prefix_cache = bool(prefix_cache)
        if self.kv_mode == "paged":
            self._paged = PagedKV.for_model(cfg, batch_slots, max_seq,
                                            block_size, kv_blocks,
                                            prefix_cache=self.prefix_cache)
            ring = self._paged.ring
            self.cache = model_zoo.make_paged_cache(
                cfg, batch_slots, self._paged.pool.num_blocks, block_size,
                ring_num_blocks=ring.num_blocks if ring is not None else 0,
                ring_width=self._paged.ring_width,
            )
        else:
            self._paged = None
            self.cache = model_zoo.make_cache(cfg, batch_slots, max_seq)
        if attn_impl == "pallas" and self._paged is None:
            meshes.record_fallback(
                "serve_attn", "impl", 0,
                "attn_impl='pallas' needs kv='paged' (the kernel walks block "
                "tables); dense layout falls back to gather attention",
            )
            attn_impl = "gather"
        self.attn_impl = attn_impl
        if step_mode == "tokens":
            kinds = {s.kind for s in segments_for(cfg)}
            if kinds != {"attn_mlp"}:
                meshes.record_fallback(
                    "serve_step", "token_batch", 0,
                    f"token-level stepping needs attention-only segments, got "
                    f"{sorted(kinds)}: recurrent state is per-slot and MoE "
                    "capacity groups see padding rows; falling back to "
                    "chunked stepping",
                )
                step_mode = "chunked"
        self.step_mode = step_mode
        self.key = jax.random.PRNGKey(seed)
        self.active: list[Request | None] = [None] * batch_slots
        # the admission queue IS the scheduler (len/bool/iter work like the
        # old list); `finished` holds every TERMINAL request — FINISHED and
        # CANCELLED_DEADLINE both land here so run() drains
        self.scheduler = scheduler
        self.preemption = (scheduler in ("priority", "wdrr")) \
            if preemption is None else bool(preemption)
        self.queue = sched.AdmissionScheduler(scheduler,
                                              tenant_weights=tenant_weights)
        self.finished: list[Request] = []
        # rids of requests in an OPEN deferral episode: blocked at the head
        # at least once since they last entered a slot. One deferral
        # *episode* per request per blocked period — the episode ends on
        # admission or cancellation, NOT when another head takes over the
        # blockage (two heads alternating under preemption is two episodes,
        # not one per alternation; pinned in tests/test_serve_scheduler.py)
        self._deferring: set[int] = set()
        # fault injection + timekeeping: the clock is THE time source for
        # submit/TTFT/deadline/wall accounting, so a fault plan's
        # VirtualClock makes deadline pressure deterministic
        self._faults = fault_plan
        self._admit_stall = 0  # steps admission stays stalled (fault)
        self._step_no = 0  # monotonic fused-step counter (fault schedule key)
        self._admit_seq = 0  # admission counter behind Request.admit_seq
        if clock is None and fault_plan is not None \
                and getattr(fault_plan, "clock", None) is not None:
            clock = fault_plan.clock
        self._clock = clock if clock is not None else time.perf_counter
        if debug_checks is None:
            env = os.environ.get("REPRO_SERVE_DEBUG_CHECKS")
            if env in ("0", "1"):
                debug_checks = env == "1"
            else:
                # on under pytest (CI test jobs inherit it), off in benches
                debug_checks = "PYTEST_CURRENT_TEST" in os.environ
        self.debug_checks = bool(debug_checks)
        # wall seconds the latest step spent inside _admit (the admission
        # portion of that step's wall_s)
        self.last_admit_s = 0.0
        self.metrics = ServeMetrics(slots=batch_slots)
        if self._paged is not None:
            self.metrics.kv_blocks_total = self._paged.pool.num_blocks

        # per-slot device-program state (held as host numpy, shipped to the
        # device as tiny arrays each step; the cache stays resident on device)
        self._positions = np.zeros(batch_slots, np.int32)
        self._prompt_buf = np.zeros((batch_slots, max_seq), np.int32)
        self._prompt_len = np.zeros(batch_slots, np.int32)
        self._last_tok = np.zeros(batch_slots, np.int32)
        self._active_mask = np.zeros(batch_slots, bool)
        # the prompt buffer is the one per-slot array that is not O(slots):
        # keep its device copy resident and refresh it only on admission
        self._prompt_buf_dev = jnp.asarray(self._prompt_buf)
        # block tables ship as tiny int32 arrays, refreshed only when the
        # allocator maps or releases blocks (dense mode passes empty dummies)
        self._no_table = jnp.zeros((0,), jnp.int32)
        self._table_dev = self._ring_dev = self._no_table
        self._tables_fresh = False
        # prefix-sharing bookkeeping: each occupied slot's feed-block content
        # keys and the watermark of blocks already registered in the index
        self._slot_keys: list[list | None] = [None] * batch_slots
        self._reg_upto = np.zeros(batch_slots, np.int32)

        self.mesh = mesh
        self.last_sharded_path: tuple | None = None
        if mesh is not None:
            self.last_sharded_path = self.sharded_path(mesh)
            with meshes.use_mesh(mesh):
                cache_sh = meshes.tree_shardings(
                    model_zoo.cache_specs(self.cache,
                                          paged=self._paged is not None),
                    self.cache, mesh,
                    rules=(meshes.SERVE_KERNEL_CACHE_RULES
                           if self.attn_impl == "pallas"
                           else meshes.SERVE_CACHE_RULES),
                )
                self.cache = jax.device_put(self.cache, cache_sh)
                if param_specs is not None:
                    self.params = jax.device_put(
                        params, meshes.tree_shardings(param_specs, params, mesh)
                    )
                else:
                    self.params = jax.device_put(params, meshes.replicated(mesh))

        # donate the cache through both programs: the old cache is dead the
        # moment the step/reset returns, and without donation XLA keeps input
        # + output cache buffers live — a 2x peak that matters at multi-GB
        # KV-cache scale
        self._step_fn = jax.jit(self._build_step(), donate_argnums=(1,))
        self._token_step_fn = (
            jax.jit(self._build_token_step(), donate_argnums=(1,))
            if self.step_mode == "tokens" else None
        )
        self._reset_fn = jax.jit(
            functools.partial(_reset_slot_rows, paged=self._paged is not None),
            donate_argnums=(0,),
        )
        self._cow_fn = (jax.jit(_cow_copy_blocks, donate_argnums=(0,))
                        if self.prefix_cache else None)
        # bytes one written cache row costs across every non-per-slot leaf
        # (all layers; paged: full + ring regions both scatter per position)
        # — the unit behind metrics.kv_bytes_written
        self._kv_row_bytes = _cache_row_bytes(self.cache)

    # -- sharding ------------------------------------------------------------
    def sharded_path(self, mesh) -> tuple:
        """Decide how the serving caches shard on ``mesh``: returns
        ``("gspmd", data_axes, model_axis)``. The cache batch (slot) dim — or
        the block-pool dim under paged KV — goes over the data axes when it
        divides them; head/feature dims go over the model axis when the
        family has a head-partitioned cache tensor that divides it.
        Divisibility drops are recorded in ``meshes.fallbacks()`` — the same
        bookkeeping ``Engine.sharded_path`` uses — and the dropped dim stays
        replicated (GSPMD still shards whatever per-tensor dims do resolve).
        """
        data = meshes.mesh_data_axes(mesh)
        n_data = meshes.mesh_axis_size(mesh, *data) if data else 1
        if self._paged is not None:
            nb = self._paged.pool.num_blocks
            if data and self.attn_impl == "pallas":
                meshes.record_fallback(
                    "serve_cache", "kv_blocks", 1,
                    "paged-attention kernel walks the whole block pool "
                    "through its scalar-prefetched table (any token may map "
                    "any physical block); block pool stays replicated",
                )
                data = ()
            elif data and nb % n_data != 0:
                meshes.record_fallback(
                    "serve_cache", "kv_blocks", 1,
                    f"paged pool of {nb} blocks not divisible by data axes "
                    f"{data}={n_data}; block pool stays replicated",
                )
                data = ()
        elif data and self.slots % n_data != 0:
            meshes.record_fallback(
                "serve_cache", "batch", 0,
                f"batch slots {self.slots} not divisible by data axes "
                f"{data}={n_data}; cache slots stay replicated",
            )
            data = ()
        model_axis = None
        m_size = meshes.mesh_axis_size(mesh, "model")
        if m_size > 1:
            heads = self._cache_head_dim()
            if heads is None:
                meshes.record_fallback(
                    "serve_cache", "kv_heads", 2,
                    "no head-partitioned cache tensor in this family "
                    "(latent/recurrent cache); model axis shards params only",
                )
            elif heads % m_size != 0:
                meshes.record_fallback(
                    "serve_cache", "kv_heads", 2,
                    f"cache head dim {heads} not divisible by mesh axis "
                    f"'model'={m_size}; cache heads stay replicated",
                )
            else:
                model_axis = "model"
        return "gspmd", data, model_axis

    def _cache_head_dim(self) -> int | None:
        """Size of the cache dim the model axis would partition, if any."""
        cfg = self.cfg
        if cfg.family == "ssm":  # rwkv wkv state: (B, heads, hd, hd)
            return cfg.d_model // cfg.rwkv_head_size
        if cfg.attn_kind == "mla":  # latent cache has no head dim
            return None
        return cfg.n_kv_heads

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        try:
            if not req.prompt:
                raise ValueError(f"request {req.rid}: empty prompt")
            if req.max_new_tokens < 1:
                raise ValueError(
                    f"request {req.rid}: max_new_tokens must be >= 1, "
                    f"got {req.max_new_tokens}"
                )
            if len(req.prompt) >= self.max_seq:
                raise ValueError(
                    f"request {req.rid}: prompt len {len(req.prompt)} >= "
                    f"max_seq {self.max_seq}"
                )
            for name in ("deadline_ttft_s", "deadline_s"):
                d = getattr(req, name)
                if d is not None and d <= 0:
                    raise ValueError(
                        f"request {req.rid}: {name} must be > 0, got {d}"
                    )
            if self._paged is not None:
                full, _ = self._paged.required(
                    len(req.prompt), req.max_new_tokens, self.prefill_chunk,
                    token_step=self.step_mode == "tokens",
                )
                if full > self._paged.pool.num_blocks:
                    # deferral only makes sense when finish-time releases can
                    # ever satisfy it; an impossible request must fail loudly
                    raise ValueError(
                        f"request {req.rid}: needs {full} KV blocks but the "
                        f"pool only has {self._paged.pool.num_blocks}"
                    )
        except ValueError:
            # fail loudly AND leave the corpse inspectable: callers that
            # catch the raise still see a terminal status on the request
            req.status = sched.REJECTED
            self.metrics.rejected += 1
            raise
        req.submit_s = self._clock()
        req.submit_step = self._step_no
        req.status = sched.QUEUED
        self.queue.push(req)

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.active):
            if r is None:
                return i
        return None

    def _head_admissible(self, head: Request) -> bool:
        """Can the paged pool cover ``head``'s worst-case reservation right
        now? Resumes reserve for ``prompt + carried output`` — the same
        positions the original reservation covered. With the prefix cache
        the reservation is net of resident shared blocks (never more than
        the unshared demand), re-planned on every check: evictions between
        checks can free shared blocks out of the index."""
        if self._paged is None:
            return True
        feed_len = len(head.prompt) + len(head.out)
        max_new = head.max_new_tokens - len(head.out)
        token_step = self.step_mode == "tokens"
        if self.prefix_cache:
            return self._paged.can_admit_shared(
                self._feed_keys(head), feed_len, max_new,
                self.prefill_chunk, token_step=token_step,
            )
        return self._paged.can_admit(feed_len, max_new, self.prefill_chunk,
                                     token_step=token_step)

    def _feed_keys(self, req: Request) -> list[tuple]:
        """Content keys of ``req``'s full feed blocks (prompt + carried
        output — a resume shares whatever prefix of its recompute is still
        resident, its own pre-eviction blocks included)."""
        return prefix_keys(req.prompt + req.out, self._paged.block_size)

    def _admit_into(self, slot: int, req: Request, now: float):
        """Bind ``req`` to ``slot``. A resumed (preempted) request feeds
        ``prompt + out`` as its prompt: the chunked re-prefill recomputes
        exactly the KV prefix its evicted cache held, and the engine's
        emit boundary (``positions + 1 >= prompt_len``) restarts emission
        right after the carried tokens — token-exact under greedy."""
        feed = req.prompt + req.out
        plen = len(feed)
        start = 0
        if self._paged is not None:
            max_new = req.max_new_tokens - len(req.out)
            token_step = self.step_mode == "tokens"
            if self.prefix_cache:
                keys = self._feed_keys(req)
                start, n_shared = self._paged.admit_shared(
                    slot, keys, plen, max_new, self.prefill_chunk,
                    token_step=token_step,
                )
                self._slot_keys[slot] = keys
                self._reg_upto[slot] = n_shared
                self._tables_fresh = False  # shared blocks mapped host-side
                if n_shared:
                    self.metrics.prefix_hits += 1
                    self.metrics.prefix_tokens += start
                    ten = self.metrics.tenant(req.tenant)
                    ten["prefix_hits"] += 1
                    ten["prefix_tokens"] += start
            else:
                self._paged.admit(slot, plen, max_new, self.prefill_chunk,
                                  token_step=token_step)
        req.prefix_shared_tokens = start
        self.active[slot] = req
        req.steps = 0
        req.status = sched.RUNNING
        req.admit_seq = self._admit_seq
        self._admit_seq += 1
        if req.admit_s is None:
            # first admission only: resumes must not inflate throughput
            # accounting (admitted counts requests, not slot bindings)
            req.admit_s = now
            self.metrics.admitted += 1
            self.metrics.prio(req.priority)["admitted"] += 1
            self.metrics.tenant(req.tenant)["admitted"] += 1
        # prefill starts past the shared prefix; the final prompt position is
        # never shared (plan_shared caps start at plen-1), so the emission
        # boundary (positions + 1 >= prompt_len) is reached by computation
        self._positions[slot] = start
        self._prompt_buf[slot] = 0
        self._prompt_buf[slot, :plen] = feed
        self._prompt_len[slot] = plen
        self._last_tok[slot] = 0
        self._active_mask[slot] = True

    def _preempt(self, slot: int):
        """Evict the request in ``slot``: release its blocks and requeue it
        carrying its generated tokens (it resumes via ``_admit_into``'s
        re-prefill). The recompute-on-resume tax — every cached position is
        recomputed — is recorded in ``metrics.recompute_tokens``."""
        req = self.active[slot]
        if self._paged is not None:
            self._paged.release(slot)
            self._tables_fresh = False
        self._slot_keys[slot] = None
        self.active[slot] = None
        self._active_mask[slot] = False
        req.status = sched.PREEMPTED
        req.preemptions += 1
        self.metrics.preemptions += 1
        self.metrics.prio(req.priority)["preemptions"] += 1
        self.metrics.tenant(req.tenant)["preemptions"] += 1
        self.metrics.recompute_tokens += int(self._positions[slot])
        self.queue.push(req)  # keeps its original seq: front of its class

    def _cancel(self, req: Request, slot: int | None):
        """Deadline miss: cancel ``req`` (terminal), freeing its slot and
        blocks immediately — overload sheds load instead of occupying."""
        if slot is not None:
            if self._paged is not None:
                self._paged.release(slot)
                self._tables_fresh = False
            self._slot_keys[slot] = None
            self.active[slot] = None
            self._active_mask[slot] = False
        req.status = sched.CANCELLED_DEADLINE
        self.finished.append(req)
        self.metrics.deadline_misses += 1
        self.metrics.prio(req.priority)["deadline_misses"] += 1
        self.metrics.tenant(req.tenant)["deadline_misses"] += 1
        self._deferring.discard(req.rid)  # episode over: cancelled

    def _sweep_deadlines(self, now: float):
        """Cancel every queued or running request past a deadline (one
        definition of "missed" for both sides: scheduler.deadline_missed)."""
        for req in self.queue.expired(now):
            self._cancel(req, slot=None)
        for i, req in enumerate(self.active):
            if req is not None and sched.deadline_missed(req, now):
                self._cancel(req, slot=i)

    def _record_first_token(self, req: Request, now: float):
        req.ttft_s = now - req.submit_s
        self.metrics.ttft_s.append(req.ttft_s)
        self.metrics.ttft_steps.append(req.steps)
        rollup = self.metrics.prio(req.priority)
        rollup["ttft_steps"].append(req.steps)
        # e2e steps: fused steps since SUBMISSION, queue wait included — the
        # number preemptive scheduling improves for the interactive class
        e2e = (self._step_no - req.submit_step + 1
               if req.submit_step is not None else req.steps)
        rollup["ttft_e2e_steps"].append(e2e)
        self.metrics.tenant(req.tenant)["ttft_e2e_steps"].append(e2e)

    def _finish(self, req: Request, slot: int):
        req.done = True
        req.status = sched.FINISHED
        self.finished.append(req)
        self.active[slot] = None
        self._active_mask[slot] = False
        self.metrics.finished += 1
        self.metrics.prio(req.priority)["finished"] += 1
        self.metrics.tenant(req.tenant)["finished"] += 1
        self._slot_keys[slot] = None
        if self._paged is not None:
            self._paged.release(slot)  # free-on-finish
            self._tables_fresh = False

    def _admit(self):
        now = self._clock()
        self._sweep_deadlines(now)
        if not self.queue:
            return
        if self.admission == "drain" and any(r is not None for r in self.active):
            return  # static batching: refill only once the batch has drained
        newly = []
        while self.queue:
            head = self.queue.peek()
            free = self._free_slot()
            ok = self._head_admissible(head)
            if free is None or not ok:
                # head is blocked (no slot / pool can't cover it). Preemption
                # may clear the blockage by evicting a STRICTLY lower-priority
                # victim — the strict inequality is the termination argument:
                # heads pop in non-decreasing priority, so nothing admitted in
                # this loop can become a later head's victim.
                victim = (sched.pick_victim(self.active, below=head.priority)
                          if self.preemption and self.admission == "continuous"
                          else None)
                if victim is not None:
                    self._preempt(victim)
                    continue  # retry the head against the freed capacity
                if not ok:
                    # pool-blocked with nobody to evict: defer (head-of-line —
                    # skipping ahead would starve long prompts) until
                    # finish-time releases free capacity. Never admit into a
                    # future OOM. One deferral *episode* per request per
                    # blocked period (a request blocked for ten steps is one
                    # deferred request, not ten) — tracked as a SET of open
                    # episodes, ended only by admission or cancellation:
                    # when two heads alternate under preemption (A blocked,
                    # B blocked, A blocked again), A's episode is still the
                    # same blockage and must not re-count.
                    if head.rid not in self._deferring:
                        self._deferring.add(head.rid)
                        self.metrics.deferrals += 1
                    self.metrics.deferral_steps += 1
                break
            req = self.queue.pop()
            self._deferring.discard(req.rid)  # episode over: admitted
            self._admit_into(free, req, now)
            newly.append(free)
        if newly:
            # reset the freed slots' per-slot cache rows: recurrent state
            # (wkv/ssm/conv/shift) must start from zeros; dense KV rows get
            # zeroed too, belt-and-braces on top of the per-row validity
            # masks (paged block pools skip this — recycled blocks are
            # invalidated by the masks alone). Fixed (slots,) index vector
            # padded with an out-of-range sentinel (scatter drops OOB rows)
            # keeps this a single compiled program that only writes the
            # admitted rows — continuous batching calls it per admission, so
            # it must not touch the whole cache
            idx = np.full(self.slots, self.slots, np.int32)
            idx[: len(newly)] = newly
            self.cache = self._reset_fn(self.cache, jnp.asarray(idx))
            self._prompt_buf_dev = jnp.asarray(self._prompt_buf)

    # -- the fused device step -------------------------------------------------
    def _build_step(self):
        cfg = self.cfg
        decode = model_zoo.decode_fn(cfg)
        temperature = self.temperature
        vocab = cfg.vocab_size
        chunk = self.prefill_chunk
        paged = self._paged
        attn_impl = self.attn_impl
        if paged is not None:
            block_size, ring_width = paged.block_size, paged.ring_width
            max_seq = self.max_seq

        # chunk == 1: every active row runs the (single) sub-step, so the
        # PR-4 semantics hold as-is — inactive rows' dummy writes land at
        # their parked position behind the validity masks and are reset on
        # admission — and skipping the select keeps the donated cache an
        # in-place update. chunk > 1 needs it: an idle row's recurrent
        # state must freeze mid-chunk and a horizon-capped row must not
        # clobber its last KV row, at the cost of a per-sub-step select
        # (the write-gated dense scatter that would remove it is ROADMAP'd).
        gate_idle_rows = chunk > 1

        def select_rows(run, new, old):
            """Keep ``old`` for rows that did not run this sub-step. Cache
            leaves carry the slot dim at axis 1 ((L, B, ...)); paged block
            leaves have no slot rows — their writes were already gated by
            the write-ok sentinel inside the attention scatter."""

            def one(path, n, o):
                if paged is not None and _leaf_key(path) not in _PER_SLOT_KEYS:
                    return n
                m = run.reshape((1, run.shape[0]) + (1,) * (n.ndim - 2))
                return jnp.where(m, n, o)

            return jax.tree_util.tree_map_with_path(one, new, old)

        seq_limit = self.max_seq

        def step(params, cache, positions, prompt_buf, prompt_len, last_tok,
                 active, key, table, ring_table):
            b = positions.shape[0]
            rows = jnp.arange(b)

            # chunked stepping: C masked sub-steps inside the ONE jitted
            # program, each one a full one-token decode for every running
            # slot (prefill feeds the prompt buffer, decode feeds the last
            # sample — every sub-step does useful work for every row). Rows
            # at the max_seq horizon idle with cache/state/position frozen,
            # so C=1 reproduces the one-token engine bit for bit and any C
            # is token-exact against it.
            def substep(carry, _):
                cache, positions, last_tok, key = carry
                run = active & (positions < seq_limit)
                in_prompt = positions < prompt_len
                idx = jnp.clip(positions, 0, prompt_buf.shape[1] - 1)
                tok = jnp.where(in_prompt, prompt_buf[rows, idx], last_tok)
                tok = jnp.where(run, tok, 0).astype(jnp.int32)
                if paged is not None:
                    ctx = {
                        "table": table, "ring_table": ring_table,
                        "write_ok": run, "block_size": block_size,
                        "ring_width": ring_width, "max_seq": max_seq,
                        "impl": attn_impl,
                    }
                    logits, new_cache = decode(params, tok, cache, positions,
                                               paged=ctx)
                else:
                    logits, new_cache = decode(params, tok, cache, positions)
                cache = (select_rows(run, new_cache, cache)
                         if gate_idle_rows else new_cache)
                logits = logits[:, :vocab].astype(jnp.float32)
                if temperature > 0:
                    key, sub = jax.random.split(key)
                    nxt = jax.random.categorical(sub, logits / temperature,
                                                 axis=-1)
                else:
                    nxt = jnp.argmax(logits, axis=-1)
                nxt = nxt.astype(jnp.int32)
                # the sample is a real generation once the prompt is consumed
                emit = run & (positions + 1 >= prompt_len)
                positions = jnp.where(run, positions + 1, positions)
                last_tok = jnp.where(run, nxt, last_tok)
                return (cache, positions, last_tok, key), (nxt, emit)

            init = (cache, positions, last_tok, key)
            (cache, positions, last_tok, key), (toks, emits) = jax.lax.scan(
                substep, init, None, length=chunk
            )
            # toks/emits: (C, B) — the host truncates at max_new_tokens
            return cache, positions, last_tok, key, toks, emits

        return step

    def _build_token_step(self):
        """Fused decode over a flattened (T,) token batch. ``tokens``/
        ``slot``/``pos``/``live`` come from the host scheduler
        (``_step_tokens``): ``slot`` maps each row onto its cache slot,
        ``live`` gates padding rows out of cache writes. Returns per-row
        next-token samples; the host reads each slot's last scheduled row.
        Per-slot recurrent gating (``select_rows``) is unnecessary here:
        eligible families are attention-only, and every cache mutation is a
        scatter already gated by ``write_ok``."""
        cfg = self.cfg
        decode = model_zoo.decode_fn(cfg)
        temperature = self.temperature
        vocab = cfg.vocab_size
        paged = self._paged
        attn_impl = self.attn_impl
        if paged is not None:
            block_size, ring_width = paged.block_size, paged.ring_width
            max_seq = self.max_seq

        def step(params, cache, tokens, slot, pos, live, key, table,
                 ring_table):
            tok = jnp.where(live, tokens, 0).astype(jnp.int32)
            if paged is not None:
                ctx = {
                    # per-token tables: row i is token i's slot's table
                    "table": table, "ring_table": ring_table,
                    "write_ok": live, "block_size": block_size,
                    "ring_width": ring_width, "max_seq": max_seq,
                    "impl": attn_impl,
                }
                logits, cache = decode(params, tok, cache, pos, paged=ctx,
                                       slot=slot, write_ok=live)
            else:
                logits, cache = decode(params, tok, cache, pos,
                                       slot=slot, write_ok=live)
            logits = logits[:, :vocab].astype(jnp.float32)
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature,
                                             axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            return cache, nxt.astype(jnp.int32), key

        return step

    # -- stepping ---------------------------------------------------------------
    @property
    def step_no(self) -> int:
        """Monotonic fused-step count so far — the clock trace replay
        (``serve.faults.replay_trace``) schedules arrivals against."""
        return self._step_no

    def step(self):
        """Apply scheduled faults, admit into free slots (unless stalled),
        then one fused decode step. Wall time (``metrics.wall_s``) covers
        the whole step, admission included; ``last_admit_s`` records the
        admission portion so the split stays assertable."""
        t0 = self._clock()
        if self._faults is not None:
            self._faults.apply(self, self._step_no)
        if self._admit_stall > 0:
            # admission stalled by a fault: deadlines still sweep (a stalled
            # server must still shed load) but nothing enters a slot
            self._admit_stall -= 1
            self._sweep_deadlines(self._clock())
        else:
            self._admit()
        self.last_admit_s = self._clock() - t0
        if self.step_mode == "tokens":
            self._step_tokens(t0)
        else:
            self._step_chunked(t0)
        self._step_no += 1
        if self.debug_checks and self._paged is not None:
            # allocator invariants checked at the step that broke them, not
            # steps later when a recycled block shows up in two tables
            self._paged.check()

    def _ensure_or_preempt(self, slot: int, pos: int, n: int,
                           cow_pairs: list | None = None) -> bool:
        """``ensure_step`` + copy-on-write that never lets ``PoolExhausted``
        escape: mid-run pressure (a fault plan shrinking the pool out from
        under admission's reservations) evicts victims until the write fits,
        the failing slot itself last. Shared blocks in the write range are
        COW-split here — ``cow_pairs`` accumulates the (old, new) splits the
        caller must device-copy before the step (splits that landed before a
        mid-loop eviction stay in the list: copying a row that was since
        freed is harmless, unwritten rows are masked invalid for any later
        owner). Returns True when any table changed (mapping, split OR
        eviction)."""
        changed = False
        while True:
            try:
                changed |= self._paged.ensure_step(slot, pos, n)
                if cow_pairs is not None and self.prefix_cache:
                    before = len(cow_pairs)
                    self._paged.cow_step(slot, pos, n, out=cow_pairs)
                    changed |= len(cow_pairs) > before
                return changed
            except PoolExhausted:
                # a partial mapping/split may have landed before the raise
                changed = True
                victim = sched.pick_victim(self.active, below=None)
                if victim is None or victim == slot:
                    # nobody else to evict: the failing slot yields and
                    # resumes once the pool heals/frees
                    self._preempt(slot)
                    return changed
                self._preempt(victim)

    def _apply_cow(self, pairs: list[tuple[int, int]]):
        """Run the device-side half of the COW splits: copy each old block's
        rows into the new private block before the fused step scatters into
        it. Index vectors pad to 4-entry buckets (src clamps to a real
        block, dst pads out-of-range so the scatter drops it) to bound the
        compiled-shape set."""
        nb = self._paged.pool.num_blocks
        self.metrics.cow_splits += len(pairs)
        self.metrics.kv_bytes_written += (
            len(pairs) * self._paged.block_size * self._kv_row_bytes
        )
        for k in range(0, len(pairs), 4):
            batch = pairs[k:k + 4]
            src = np.zeros(4, np.int32)
            dst = np.full(4, nb, np.int32)
            src[:len(batch)] = [p[0] for p in batch]
            dst[:len(batch)] = [p[1] for p in batch]
            ctx = (meshes.use_mesh(self.mesh) if self.mesh is not None
                   else contextlib.nullcontext())
            with ctx:
                self.cache = self._cow_fn(self.cache, jnp.asarray(src),
                                          jnp.asarray(dst))

    def _register_prefix(self, slot: int):
        """Advance ``slot``'s prefix-index registration watermark: feed
        blocks whose last row the slot's position has passed are fully
        written (shared ones were already valid) and become shareable. Runs
        before any finish-time release — a released block is evicted from
        the index by the refcount-zero hook, never registered dead."""
        keys = self._slot_keys[slot]
        if keys is None:
            return
        upto = min(int(self._positions[slot]) // self._paged.block_size,
                   len(keys))
        if upto > self._reg_upto[slot]:
            self._reg_upto[slot] = self._paged.register_blocks(
                slot, keys, int(self._reg_upto[slot]), upto
            )

    def _step_chunked(self, t0: float):
        """C uniform masked sub-steps across all slots (the reference)."""
        # block allocation counts into wall time too: the paged-only host
        # work (ensure_step + table upload) must count against paged wall
        # time, or the CI-gated paged-vs-dense tok/s ratio flatters paged
        if self._paged is not None:
            # alloc-on-write: map blocks for the rows each slot writes this
            # step (guaranteed to succeed when the pool is unfaulted —
            # admission reserved the worst case; under injected shrinkage
            # _ensure_or_preempt evicts to fit), COW-splitting any block
            # still shared with another slot before the scatter lands
            changed = False
            cow_pairs: list[tuple[int, int]] = []
            for i in range(self.slots):
                if self.active[i] is None:
                    continue
                pos = int(self._positions[i])
                n = min(self.prefill_chunk, self.max_seq - pos)
                if n > 0:
                    changed |= self._ensure_or_preempt(i, pos, n, cow_pairs)
            if cow_pairs:
                self._apply_cow(cow_pairs)
            if changed or not self._tables_fresh:
                tf, tr = self._paged.tables()
                self._table_dev = jnp.asarray(tf)
                self._ring_dev = (jnp.asarray(tr) if tr is not None
                                  else self._no_table)
                self._tables_fresh = True
            self.metrics.kv_blocks_peak = max(
                self.metrics.kv_blocks_peak, self._paged.pool.blocks_in_use
            )
        old_pos = self._positions.copy()
        ctx = (meshes.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            out = self._step_fn(
                self.params, self.cache,
                jnp.asarray(self._positions), self._prompt_buf_dev,
                jnp.asarray(self._prompt_len), jnp.asarray(self._last_tok),
                jnp.asarray(self._active_mask), self.key,
                self._table_dev, self._ring_dev,
            )
        self.cache, positions, last_tok, self.key, toks, emits = out
        toks = np.asarray(toks)  # (C, B)
        emits = np.asarray(emits)  # sync point: one per step
        # np.array (not asarray): device arrays view as read-only numpy, and
        # _admit writes these in place on admission
        self._positions = np.array(positions)
        self._last_tok = np.array(last_tok)
        now = self._clock()

        n_active = 0
        generated = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            n_active += 1
            req.steps += 1
            plen = int(self._prompt_len[i])
            # prefill vs decode token split: prompt tokens fed this step
            # (chunked stepping feeds up to C), generations counted on emit
            fed = (min(int(self._positions[i]), plen)
                   - min(int(old_pos[i]), plen))
            self.metrics.prompt_tokens += fed
            ten = self.metrics.tenant(req.tenant)
            ten["prompt_tokens"] += fed
            emitted = 0
            for j in range(toks.shape[0]):
                # truncate at max_new: the device may over-generate up to
                # C-1 tokens in the final chunk of a request
                if not emits[j, i] or len(req.out) >= req.max_new_tokens:
                    continue
                req.out.append(int(toks[j, i]))
                emitted += 1
                if req.ttft_s is None:
                    self._record_first_token(req, now)
            generated += emitted
            ten["tokens_generated"] += emitted
            # index the newly completed feed blocks BEFORE any finish-time
            # release: freed blocks must never enter the index
            self._register_prefix(i)
            if (len(req.out) >= req.max_new_tokens
                    or int(self._positions[i]) >= self.max_seq):
                self._finish(req, i)
        self.metrics.steps += 1
        self.metrics.active_slot_steps += n_active
        self.metrics.tokens_generated += generated
        # chunked honesty: the fused program computes every slot row for all
        # C sub-steps, live or not
        self.metrics.batched_tokens += self.slots * self.prefill_chunk
        # KV traffic: every advanced position scattered one row into each
        # cache region (COW copy bytes were added by _apply_cow)
        self.metrics.kv_bytes_written += (
            int((self._positions - old_pos).sum()) * self._kv_row_bytes
        )
        self.metrics.wall_s += now - t0

    def _step_tokens(self, t0: float):
        """One variable-composition token batch (vLLM-style): prefilling
        slots schedule ``min(C, remaining prompt)`` rows, decoding slots one
        row each, flattened into a single fused decode whose FLOPs scale
        with live tokens. Token-exact against chunked stepping — every
        scheduled row is the same one-token decode at the same position —
        with two differences that cannot change tokens: prompt-overshoot
        rows are never scheduled, and idle slots contribute no rows."""
        chunk = self.prefill_chunk
        work: list[tuple[int, int, int]] = []  # (slot, start_pos, n_rows)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            p = int(self._positions[i])
            plen = int(self._prompt_len[i])
            n = min(chunk, plen - p) if p < plen else 1
            n = min(n, self.max_seq - p)
            work.append((i, p, n))
        if self._paged is not None:
            # map blocks BEFORE building the flat batch: under injected pool
            # shrinkage _ensure_or_preempt may evict slots, and an evicted
            # slot must not schedule rows this step. COW splits land here
            # too — before the per-token tables are gathered
            cow_pairs: list[tuple[int, int]] = []
            for i, p, n in work:
                if self.active[i] is not None:
                    self._ensure_or_preempt(i, p, n, cow_pairs)
            if cow_pairs:
                self._apply_cow(cow_pairs)
            work = [(i, p, n) for i, p, n in work
                    if self.active[i] is not None]
        t_live = sum(n for _, _, n in work)
        if t_live == 0:
            # nothing runnable this step (empty batch); still a step
            self.metrics.steps += 1
            self.metrics.wall_s += self._clock() - t0
            return
        # pad the batch to an 8-token bucket: bounds the set of distinct
        # shapes the jitted step compiles for; padding rows are dead (live
        # False gates their writes, their samples are never read)
        t_pad = max(8, -(-t_live // 8) * 8)
        tokens = np.zeros(t_pad, np.int32)
        slot_ids = np.zeros(t_pad, np.int32)
        pos = np.zeros(t_pad, np.int32)
        live = np.zeros(t_pad, bool)
        last_row: dict[int, int] = {}
        k = 0
        for i, p, n in work:
            plen = int(self._prompt_len[i])
            if p < plen:
                tokens[k:k + n] = self._prompt_buf[i, p:p + n]
            else:
                tokens[k] = self._last_tok[i]
            slot_ids[k:k + n] = i
            pos[k:k + n] = np.arange(p, p + n, dtype=np.int32)
            live[k:k + n] = True
            last_row[i] = k + n - 1
            k += n
        if self._paged is not None:
            tf, tr = self._paged.token_tables(slot_ids)
            table_dev = jnp.asarray(tf)
            ring_dev = (jnp.asarray(tr) if tr is not None
                        else self._no_table)
            self.metrics.kv_blocks_peak = max(
                self.metrics.kv_blocks_peak, self._paged.pool.blocks_in_use
            )
        else:
            table_dev = ring_dev = self._no_table
        ctx = (meshes.use_mesh(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            self.cache, nxt, self.key = self._token_step_fn(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(slot_ids), jnp.asarray(pos), jnp.asarray(live),
                self.key, table_dev, ring_dev,
            )
        nxt = np.asarray(nxt)  # sync point: one per step
        now = self._clock()

        n_active = 0
        generated = 0
        for i, p, n in work:
            req = self.active[i]
            n_active += 1
            req.steps += 1
            plen = int(self._prompt_len[i])
            new_p = p + n
            self._positions[i] = new_p
            fed = min(new_p, plen) - min(p, plen)
            self.metrics.prompt_tokens += fed
            ten = self.metrics.tenant(req.tenant)
            ten["prompt_tokens"] += fed
            if new_p >= plen:
                # the slot's last scheduled row sits at the final prompt
                # position or beyond: its sample is a real generation
                tok = int(nxt[last_row[i]])
                self._last_tok[i] = tok
                if len(req.out) < req.max_new_tokens:
                    req.out.append(tok)
                    generated += 1
                    ten["tokens_generated"] += 1
                    if req.ttft_s is None:
                        self._record_first_token(req, now)
            # index the newly completed feed blocks BEFORE any finish-time
            # release: freed blocks must never enter the index
            self._register_prefix(i)
            if (len(req.out) >= req.max_new_tokens
                    or new_p >= self.max_seq):
                self._finish(req, i)
        self.metrics.steps += 1
        self.metrics.active_slot_steps += n_active
        self.metrics.tokens_generated += generated
        self.metrics.batched_tokens += t_live
        # KV traffic: every live row scattered once into each cache region
        # (COW copy bytes were added by _apply_cow)
        self.metrics.kv_bytes_written += t_live * self._kv_row_bytes
        self.metrics.wall_s += now - t0

    def reset_metrics(self):
        kv_total = self.metrics.kv_blocks_total
        self.metrics = ServeMetrics(slots=self.slots, kv_blocks_total=kv_total)

    def run(self, max_steps: int | None = None) -> list[Request]:
        """Step until queue and slots drain (or ``max_steps``); returns ALL
        terminal requests so far (``FINISHED`` and ``CANCELLED_DEADLINE``
        both land in ``finished``), in deterministic ``rid`` order."""
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) and (
            max_steps is None or steps < max_steps
        ):
            self.step()
            steps += 1
        return sorted(self.finished, key=lambda r: r.rid)


def generate_greedy(cfg: ModelConfig, params, prompts: list[list[int]],
                    max_new_tokens: int, max_seq: int | None = None):
    """Convenience: run a batch of prompts to completion, return token lists
    (rid order == prompt order, straight from ``run``)."""
    # `is None`, not `or`: max_seq=0 must reach BatchedServer's >= 1 check
    # as the caller's value, not silently become a derived default
    if max_seq is None:
        max_seq = max(len(p) for p in prompts) + max_new_tokens + 1
    server = BatchedServer(cfg, params, batch_slots=len(prompts), max_seq=max_seq)
    for i, p in enumerate(prompts):
        server.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new_tokens))
    return [r.out for r in server.run()]


def score_tokens(cfg: ModelConfig, params, prompts: list[list[int]],
                 max_new_tokens: int, batch_slots: int | None = None,
                 max_seq: int | None = None, **server_kwargs):
    """Batch-scoring session for the db/ PREDICT path: run all prompts to
    completion on a short-lived server and return ``(outputs, metrics)``.

    Outputs are token lists in prompt order; ``metrics`` is the session's
    ``ServeMetrics`` (None when there were no prompts — e.g. a WHERE clause
    filtered every row, so nothing ever reaches the server). Unlike
    ``generate_greedy`` the slot count is capped, so a million-row scoring
    query doesn't try to allocate a million slots: continuous batching
    refills slots as prompts finish.
    """
    if not prompts:
        return [], None
    if max_seq is None:
        max_seq = max(len(p) for p in prompts) + max_new_tokens + 1
    if batch_slots is None:
        batch_slots = min(len(prompts), 8)
    server = BatchedServer(
        cfg, params, batch_slots=batch_slots, max_seq=max_seq, **server_kwargs
    )
    for i, p in enumerate(prompts):
        server.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new_tokens))
    outs = [r.out for r in server.run()]
    return outs, server.metrics
