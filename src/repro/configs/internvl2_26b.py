"""InternVL2-26B [arXiv:2404.16821]: InternViT frontend (STUB: precomputed
patch embeddings per the assignment) + InternLM2-20B language backbone.
48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92553."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=92553,
        rope_theta=1_000_000.0,
        vis_tokens=1024,  # 448x448 InternViT with pixel shuffle -> 1024 tokens
    )
