"""DeepSeek-V3-671B [arXiv:2412.19437]: MLA + MoE (1 shared + 256 routed,
top-8) + multi-token prediction. 61L d_model=7168 128H routed d_ff=2048
vocab=129280; first 3 layers dense (d_ff=18432)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=18432,  # dense layers (first_dense_layers) use this width
        vocab_size=129280,
        attn_kind="mla",
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        n_experts=256,
        experts_per_token=8,
        n_shared_experts=1,
        moe_d_ff=2048,
        first_dense_layers=3,
        mtp=True,
        rope_theta=10_000.0,
    )
