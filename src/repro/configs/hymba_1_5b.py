"""Hymba-1.5B [arXiv:2411.13676]: hybrid — parallel attention + Mamba heads
in every layer; sliding-window attention except 3 global layers (first,
middle, last). 32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001 ssm_state=16.
Meta tokens are omitted (DESIGN.md §5)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_expand=2,
        swa_window=1024,
        n_global_layers=3,
        rope_theta=10_000.0,
    )
