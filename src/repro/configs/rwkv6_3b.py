"""RWKV6-3B (Finch) [arXiv:2404.05892]: attention-free, data-dependent decay.
32L d_model=2560 d_ff=8960 vocab=65536, head size 64."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        n_layers=32,
        d_model=2560,
        n_heads=40,  # d_model / rwkv_head_size
        n_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        attn_kind="none",
        rwkv_head_size=64,
        chunk_len=32,
    )
