"""Architecture registry: the 10 assigned archs + the paper's own workloads."""
from __future__ import annotations

from repro.models.config import ModelConfig, reduced

_MODULES = {
    "minicpm3-4b": "minicpm3_4b",
    "internlm2-20b": "internlm2_20b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "deepseek-67b": "deepseek_67b",
    "internvl2-26b": "internvl2_26b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "rwkv6-3b": "rwkv6_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "hymba-1.5b": "hymba_1_5b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    import importlib

    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config()


def get_reduced_config(arch_id: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch_id), **overrides)


__all__ = ["ARCH_IDS", "get_config", "get_reduced_config", "ModelConfig"]
