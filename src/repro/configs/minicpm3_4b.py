"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: dense, MLA attention.
62L d_model=2560 40H d_ff=6400 vocab=73448."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab_size=73448,
        attn_kind="mla",
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
        rope_theta=10_000.0,
        tie_embeddings=True,
    )
