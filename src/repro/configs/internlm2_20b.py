"""InternLM2-20B [arXiv:2403.17297]: dense GQA.
48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92544."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        family="dense",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=92544,
        rope_theta=1_000_000.0,
    )
