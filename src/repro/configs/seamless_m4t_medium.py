"""SeamlessM4T-medium [arXiv:2308.11596]: encoder-decoder, multimodal.
12L enc + 12L dec, d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
The speech frontend is a STUB per the assignment: input_specs provides
precomputed frame embeddings."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        n_layers=12,  # decoder depth
        enc_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        rope_theta=10_000.0,
        tie_embeddings=True,
    )
