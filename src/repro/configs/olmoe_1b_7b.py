"""OLMoE-1B-7B [arXiv:2409.02060]: MoE, 64 experts top-8.
16L d_model=2048 16H (kv=16) expert d_ff=1024 vocab=50304."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        n_experts=64,
        experts_per_token=8,
        moe_d_ff=1024,
        rope_theta=10_000.0,
    )
